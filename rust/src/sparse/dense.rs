//! Dense matrix — the test oracle and the `dense_1000` dataset entry.

use super::{Coo, LinOp};

#[derive(Clone, Debug)]
pub struct DenseMat {
    pub nrows: usize,
    pub ncols: usize,
    /// Row-major values.
    pub a: Vec<f64>,
}

impl DenseMat {
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        Self { nrows, ncols, a: vec![0.0; nrows * ncols] }
    }

    pub fn from_coo(coo: &Coo) -> Self {
        let mut d = Self::zeros(coo.nrows, coo.ncols);
        for ((&i, &j), &v) in coo.rows.iter().zip(&coo.cols).zip(&coo.vals) {
            d.a[i as usize * coo.ncols + j as usize] += v;
        }
        d
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.ncols + j]
    }

    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.ncols);
        for i in 0..self.nrows {
            let row = &self.a[i * self.ncols..(i + 1) * self.ncols];
            y[i] = row.iter().zip(x).map(|(a, b)| a * b).sum();
        }
    }

    pub fn spmv_t(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.nrows);
        y.fill(0.0);
        for i in 0..self.nrows {
            let xi = x[i];
            let row = &self.a[i * self.ncols..(i + 1) * self.ncols];
            for (yj, &aij) in y.iter_mut().zip(row) {
                *yj += aij * xi;
            }
        }
    }
}

impl LinOp for DenseMat {
    fn dim(&self) -> usize {
        assert_eq!(self.nrows, self.ncols);
        self.nrows
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.spmv(x, y)
    }
    fn apply_t(&self, x: &[f64], y: &mut [f64]) -> Result<(), String> {
        self.spmv_t(x, y);
        Ok(())
    }
    fn diagonal(&self) -> Option<Vec<f64>> {
        Some((0..self.nrows.min(self.ncols)).map(|i| self.get(i, i)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_from_coo_and_spmv() {
        let mut coo = Coo::new(2, 3);
        coo.push(0, 0, 1.0);
        coo.push(0, 2, 2.0);
        coo.push(1, 1, 3.0);
        let d = DenseMat::from_coo(&coo);
        let mut y = vec![0.0; 2];
        d.spmv(&[1.0, 2.0, 3.0], &mut y);
        assert_eq!(y, vec![7.0, 6.0]);
        let mut yt = vec![0.0; 3];
        d.spmv_t(&[1.0, 1.0], &mut yt);
        assert_eq!(yt, vec![1.0, 3.0, 2.0]);
    }
}
