//! ELL-padded CSRC — the fixed-shape layout the Pallas kernel (L1) and the
//! XLA runtime consume. f32/i32 because that is what the AOT artifacts are
//! lowered with; the rust-native formats stay f64.
//!
//! Convention (mirrors `python/compile/kernels/csrc_spmv.py`): padding
//! slots carry `ja == row index` with zero values, so they contribute
//! nothing to either the gather or the scatter.

#[derive(Clone, Debug)]
pub struct Ell {
    /// Padded dimension (n_pad ≥ logical n).
    pub n: usize,
    /// Max lower-entries per row.
    pub w: usize,
    pub ad: Vec<f32>,       // n
    pub al: Vec<f32>,       // n*w, row-major
    pub au: Vec<f32>,       // n*w
    pub ja: Vec<i32>,       // n*w
}

impl Ell {
    pub fn empty(n: usize, w: usize) -> Ell {
        Ell {
            n,
            w,
            ad: vec![0.0; n],
            al: vec![0.0; n * w],
            au: vec![0.0; n * w],
            ja: vec![0; n * w],
        }
    }

    /// Reference SpMV on the ELL layout (mirrors python ref.py) — used to
    /// cross-check the XLA runtime path from rust.
    pub fn spmv_ref(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n);
        let mut y: Vec<f32> = (0..self.n).map(|i| self.ad[i] * x[i]).collect();
        for i in 0..self.n {
            let xi = x[i];
            for s in 0..self.w {
                let k = i * self.w + s;
                let j = self.ja[k] as usize;
                y[i] += self.al[k] * x[j];
                y[j] += self.au[k] * xi;
            }
        }
        y
    }

    /// Validity: padding/index invariants the kernel relies on.
    pub fn validate(&self) -> Result<(), String> {
        if self.ad.len() != self.n
            || self.al.len() != self.n * self.w
            || self.au.len() != self.n * self.w
            || self.ja.len() != self.n * self.w
        {
            return Err("array length mismatch".into());
        }
        for i in 0..self.n {
            for s in 0..self.w {
                let k = i * self.w + s;
                let j = self.ja[k];
                if j < 0 || j as usize >= self.n {
                    return Err(format!("ja[{i},{s}] = {j} out of range"));
                }
                if j as usize > i && (self.al[k] != 0.0 || self.au[k] != 0.0) {
                    return Err(format!(
                        "ja[{i},{s}] = {j} is not strictly lower but has a value"
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_valid_and_zero() {
        let e = Ell::empty(8, 3);
        // Padding must point at the row itself to be valid.
        let mut e2 = e.clone();
        for i in 0..8 {
            for s in 0..3 {
                e2.ja[i * 3 + s] = i as i32;
            }
        }
        assert!(e2.validate().is_ok());
        let y = e2.spmv_ref(&vec![1.0; 8]);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn validate_catches_bad_index() {
        let mut e = Ell::empty(4, 1);
        e.ja[0] = 9;
        assert!(e.validate().is_err());
    }

    #[test]
    fn validate_catches_upper_value() {
        let mut e = Ell::empty(4, 1);
        for i in 0..4 {
            e.ja[i] = i as i32;
        }
        e.ja[0] = 2; // row 0 pointing above the diagonal...
        e.al[0] = 1.0; // ...with a value => invalid
        assert!(e.validate().is_err());
    }

    #[test]
    fn spmv_ref_single_pair() {
        let mut e = Ell::empty(4, 1);
        for i in 0..4 {
            e.ja[i] = i as i32;
            e.ad[i] = 1.0;
        }
        e.ja[3] = 1;
        e.al[3] = 2.0; // a[3][1] = 2
        e.au[3] = 5.0; // a[1][3] = 5
        let y = e.spmv_ref(&[1.0, 10.0, 100.0, 1000.0]);
        assert_eq!(y, vec![1.0, 10.0 + 5000.0, 100.0, 1000.0 + 20.0]);
    }
}
