//! BCSR — block compressed sparse row (r×c dense blocks), the register-
//! blocking baseline the related-work section (§1.1: Im & Yelick, Buluç
//! et al., Liu et al.) compares against. Zero-fill inside blocks trades
//! index overhead for wasted flops.

use super::{Csr, LinOp, SpmvKernel};
#[cfg(test)]
use super::Coo;

#[derive(Clone, Debug)]
pub struct Bcsr {
    pub nrows: usize,
    pub ncols: usize,
    pub r: usize,
    pub c: usize,
    /// Block-row pointers (len nrows/r + 1).
    pub ia: Vec<u32>,
    /// Block-column indices.
    pub ja: Vec<u32>,
    /// Block values, each block r*c row-major, contiguous.
    pub a: Vec<f64>,
}

impl Bcsr {
    /// Build from CSR with r×c blocking (nrows, ncols need not divide
    /// evenly; edge blocks are zero-padded logically via bounds checks at
    /// multiply time — we pad the value array, the standard approach).
    pub fn from_csr(csr: &Csr, r: usize, c: usize) -> Bcsr {
        assert!(r > 0 && c > 0);
        let nbr = csr.nrows.div_ceil(r);
        let mut ia = vec![0u32; nbr + 1];
        let mut ja: Vec<u32> = Vec::new();
        let mut a: Vec<f64> = Vec::new();
        let mut block_of: Vec<i64> = vec![-1; csr.ncols.div_ceil(c)];
        for br in 0..nbr {
            let row_start = ja.len();
            for i in br * r..((br + 1) * r).min(csr.nrows) {
                for k in csr.row_range(i) {
                    let bc = csr.ja[k] as usize / c;
                    let slot = if block_of[bc] >= row_start as i64 {
                        block_of[bc] as usize
                    } else {
                        block_of[bc] = ja.len() as i64;
                        ja.push(bc as u32);
                        a.extend(std::iter::repeat(0.0).take(r * c));
                        ja.len() - 1
                    };
                    let (ri, ci) = (i - br * r, csr.ja[k] as usize - bc * c);
                    a[slot * r * c + ri * c + ci] += csr.a[k];
                }
            }
            ia[br + 1] = ja.len() as u32;
        }
        Bcsr { nrows: csr.nrows, ncols: csr.ncols, r, c, ia, ja, a }
    }

    pub fn nblocks(&self) -> usize {
        self.ja.len()
    }

    /// Stored values including zero-fill.
    pub fn stored_values(&self) -> usize {
        self.a.len()
    }

    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.ncols);
        debug_assert_eq!(y.len(), self.nrows);
        let (r, c) = (self.r, self.c);
        let nbr = self.nrows.div_ceil(r);
        for br in 0..nbr {
            let i0 = br * r;
            let rows = r.min(self.nrows - i0);
            let mut acc = [0.0f64; 8]; // r <= 8 supported
            assert!(r <= 8, "BCSR supports block rows up to 8");
            for s in acc.iter_mut() {
                *s = 0.0;
            }
            for kb in self.ia[br] as usize..self.ia[br + 1] as usize {
                let j0 = self.ja[kb] as usize * c;
                let cols = c.min(self.ncols - j0);
                let blk = &self.a[kb * r * c..(kb + 1) * r * c];
                for ri in 0..rows {
                    let mut t = 0.0;
                    for ci in 0..cols {
                        t += blk[ri * c + ci] * x[j0 + ci];
                    }
                    acc[ri] += t;
                }
            }
            for (ri, &v) in acc.iter().take(rows).enumerate() {
                y[i0 + ri] = v;
            }
        }
    }

    /// Fill ratio: stored values / true non-zeros (≥ 1; the blocking cost).
    pub fn fill_ratio(&self, true_nnz: usize) -> f64 {
        self.stored_values() as f64 / true_nnz as f64
    }

    /// One row's dot product against x (shared by the kernel sweeps).
    /// Scans the row's block row and picks out scalar row `i`.
    #[inline]
    fn row_dot(&self, x: &[f64], i: usize) -> f64 {
        let (r, c) = (self.r, self.c);
        let br = i / r;
        let ri = i - br * r;
        let mut t = 0.0;
        for kb in self.ia[br] as usize..self.ia[br + 1] as usize {
            let j0 = self.ja[kb] as usize * c;
            let cols = c.min(self.ncols - j0);
            let blk = &self.a[kb * r * c..(kb + 1) * r * c];
            for ci in 0..cols {
                t += blk[ri * c + ci] * x[j0 + ci];
            }
        }
        t
    }

    /// k-wide analogue of [`Bcsr::row_dot`]: accumulate row i's dot
    /// products against a row-major n×k panel into `out[0..kc]` for the
    /// column window `[c0, c0 + kc)`. One scan of the block row serves
    /// the whole register panel.
    #[inline]
    fn row_dot_panel(&self, x: &[f64], k: usize, i: usize, c0: usize, out: &mut [f64]) {
        let (r, c) = (self.r, self.c);
        let br = i / r;
        let ri = i - br * r;
        let kc = out.len();
        for kb in self.ia[br] as usize..self.ia[br + 1] as usize {
            let j0 = self.ja[kb] as usize * c;
            let cols = c.min(self.ncols - j0);
            let blk = &self.a[kb * r * c..(kb + 1) * r * c];
            for ci in 0..cols {
                let v = blk[ri * c + ci];
                let xj = (j0 + ci) * k + c0;
                for (cc, o) in out.iter_mut().enumerate().take(kc) {
                    *o += v * x[xj + cc];
                }
            }
        }
    }
}

impl SpmvKernel for Bcsr {
    fn dim(&self) -> usize {
        assert_eq!(self.nrows, self.ncols, "SpmvKernel needs a square BCSR");
        self.nrows
    }

    /// Block rows pay for zero-fill too: count stored slots, not true nnz.
    fn row_work(&self, i: usize) -> usize {
        let br = i / self.r;
        1 + (self.ia[br + 1] - self.ia[br]) as usize * self.c
    }

    fn row_write_lo(&self, i: usize) -> usize {
        i
    }

    fn scatter_targets(&self, _i: usize, _visit: &mut dyn FnMut(usize)) {
        // No scatters: BCSR row sweeps are already race-free.
    }

    fn sweep_rows_into(&self, x: &[f64], r0: usize, r1: usize, buf: &mut [f64], lo: usize) {
        assert!(r1 <= self.nrows && x.len() == self.ncols);
        for i in r0..r1 {
            buf[i - lo] += self.row_dot(x, i);
        }
    }

    unsafe fn sweep_row_shared(&self, x: &[f64], i: usize, y: *mut f64) {
        *y.add(i) += self.row_dot(x, i);
    }

    fn sweep_row_contribs(&self, x: &[f64], i: usize, emit: &mut dyn FnMut(usize, f64)) {
        emit(i, self.row_dot(x, i));
    }

    fn sweep_full(&self, x: &[f64], y: &mut [f64]) {
        self.spmv(x, y);
    }

    fn sweep_rows_into_multi(
        &self,
        x: &[f64],
        k: usize,
        r0: usize,
        r1: usize,
        buf: &mut [f64],
        lo: usize,
    ) {
        assert!(k >= 1 && r1 <= self.nrows && x.len() == self.ncols * k);
        let mut c0 = 0usize;
        while c0 < k {
            let kc = (k - c0).min(8);
            let mut t = [0.0f64; 8];
            for i in r0..r1 {
                t[..kc].fill(0.0);
                self.row_dot_panel(x, k, i, c0, &mut t[..kc]);
                let yi = (i - lo) * k + c0;
                for c in 0..kc {
                    buf[yi + c] += t[c];
                }
            }
            c0 += kc;
        }
    }

    unsafe fn sweep_row_shared_multi(&self, x: &[f64], k: usize, i: usize, y: *mut f64) {
        let mut c0 = 0usize;
        while c0 < k {
            let kc = (k - c0).min(8);
            let mut t = [0.0f64; 8];
            t[..kc].fill(0.0);
            self.row_dot_panel(x, k, i, c0, &mut t[..kc]);
            for c in 0..kc {
                *y.add(i * k + c0 + c) += t[c];
            }
            c0 += kc;
        }
    }

    fn sweep_row_contribs_multi(
        &self,
        x: &[f64],
        k: usize,
        i: usize,
        emit: &mut dyn FnMut(usize, f64),
    ) {
        let mut c0 = 0usize;
        while c0 < k {
            let kc = (k - c0).min(8);
            let mut t = [0.0f64; 8];
            t[..kc].fill(0.0);
            self.row_dot_panel(x, k, i, c0, &mut t[..kc]);
            for c in 0..kc {
                emit(i * k + c0 + c, t[c]);
            }
            c0 += kc;
        }
    }

    fn kernel_name(&self) -> &'static str {
        "bcsr"
    }
}

impl LinOp for Bcsr {
    fn dim(&self) -> usize {
        assert_eq!(self.nrows, self.ncols);
        self.nrows
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.spmv(x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{propcheck, Rng};

    #[test]
    fn bcsr_matches_csr_exact_tiling() {
        let mut rng = Rng::new(10);
        let coo = Coo::random_structurally_symmetric(32, 4, false, &mut rng);
        let csr = Csr::from_coo(&coo);
        for (r, c) in [(1, 1), (2, 2), (4, 4), (2, 4)] {
            let b = Bcsr::from_csr(&csr, r, c);
            let x: Vec<f64> = (0..32).map(|_| rng.normal()).collect();
            let (mut y1, mut y2) = (vec![0.0; 32], vec![0.0; 32]);
            csr.spmv(&x, &mut y1);
            b.spmv(&x, &mut y2);
            propcheck::assert_close(&y1, &y2, 1e-12, 1e-12)
                .unwrap_or_else(|e| panic!("block {r}x{c}: {e}"));
        }
    }

    #[test]
    fn bcsr_handles_ragged_edges() {
        let mut rng = Rng::new(11);
        let coo = Coo::random_structurally_symmetric(37, 3, false, &mut rng); // 37 % 2 != 0
        let csr = Csr::from_coo(&coo);
        let b = Bcsr::from_csr(&csr, 2, 3);
        let x: Vec<f64> = (0..37).map(|_| rng.normal()).collect();
        let (mut y1, mut y2) = (vec![0.0; 37], vec![0.0; 37]);
        csr.spmv(&x, &mut y1);
        b.spmv(&x, &mut y2);
        propcheck::assert_close(&y1, &y2, 1e-12, 1e-12).unwrap();
    }

    #[test]
    fn fill_ratio_at_least_one() {
        let mut rng = Rng::new(12);
        let coo = Coo::random_structurally_symmetric(24, 2, false, &mut rng);
        let csr = Csr::from_coo(&coo);
        let b = Bcsr::from_csr(&csr, 2, 2);
        assert!(b.fill_ratio(csr.nnz()) >= 1.0);
        let b1 = Bcsr::from_csr(&csr, 1, 1);
        assert_eq!(b1.fill_ratio(csr.nnz()), 1.0);
    }

    #[test]
    fn property_bcsr_vs_csr() {
        propcheck::check(15, |rng| {
            let n = 4 + rng.below(40);
            let coo = Coo::random_structurally_symmetric(n, 3, false, rng);
            let csr = Csr::from_coo(&coo);
            let r = 1 + rng.below(4);
            let c = 1 + rng.below(4);
            let b = Bcsr::from_csr(&csr, r, c);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let (mut y1, mut y2) = (vec![0.0; n], vec![0.0; n]);
            csr.spmv(&x, &mut y1);
            b.spmv(&x, &mut y2);
            propcheck::assert_close(&y1, &y2, 1e-11, 1e-11)
        });
    }
}
