//! CSR — compressed sparse row (Saad [28]); the paper's baseline format.
//!
//! `ia(n+1)` row pointers, `ja(nnz)` column indices, `a(nnz)` values.
//! The SpMV here is the classical one whose load:flop ratio is 1.5
//! (3 nnz loads / 2 nnz flops, §4.1), against which CSRC's ≈1.26 wins.

use super::{Coo, LinOp, SpmvKernel};

#[derive(Clone, Debug)]
pub struct Csr {
    pub nrows: usize,
    pub ncols: usize,
    pub ia: Vec<u32>,
    pub ja: Vec<u32>,
    pub a: Vec<f64>,
}

impl Csr {
    /// Build from a compacted COO (sorted, deduplicated).
    pub fn from_coo(coo: &Coo) -> Csr {
        let mut ia = vec![0u32; coo.nrows + 1];
        for &i in &coo.rows {
            ia[i as usize + 1] += 1;
        }
        for i in 0..coo.nrows {
            ia[i + 1] += ia[i];
        }
        Csr {
            nrows: coo.nrows,
            ncols: coo.ncols,
            ia,
            ja: coo.cols.clone(),
            a: coo.vals.clone(),
        }
    }

    pub fn nnz(&self) -> usize {
        self.a.len()
    }

    #[inline]
    pub fn row_range(&self, i: usize) -> std::ops::Range<usize> {
        self.ia[i] as usize..self.ia[i + 1] as usize
    }

    /// Classical CSR SpMV: y = A x.
    ///
    /// Same unchecked-indexing treatment as `Csrc::spmv` so the Fig. 5
    /// comparison is optimizer-fair (the paper compares `-O3` Fortran on
    /// both sides). Safety: `ia`/`ja` are construction-validated.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        unsafe {
            for i in 0..self.nrows {
                let mut t = 0.0;
                let start = *self.ia.get_unchecked(i) as usize;
                let end = *self.ia.get_unchecked(i + 1) as usize;
                for k in start..end {
                    t += self.a.get_unchecked(k) * x.get_unchecked(*self.ja.get_unchecked(k) as usize);
                }
                *y.get_unchecked_mut(i) = t;
            }
        }
    }

    /// Row-block sweep accumulating into `buf[i - lo]` — the
    /// [`SpmvKernel`] building block. CSR scatters nothing, so only the
    /// owned rows are touched.
    #[inline]
    pub fn spmv_rows_into(&self, x: &[f64], r0: usize, r1: usize, buf: &mut [f64], lo: usize) {
        assert!(r1 <= self.nrows && x.len() == self.ncols);
        for i in r0..r1 {
            let mut t = 0.0;
            for k in self.row_range(i) {
                t += self.a[k] * x[self.ja[k] as usize];
            }
            buf[i - lo] += t;
        }
    }

    /// Multi-vector row-block sweep over row-major panels (the k-wide
    /// analogue of [`Csr::spmv_rows_into`]): `buf[(i - lo)*k + c]`
    /// accumulates column c of y_i. Reads each row's indices and values
    /// once for all k columns, in register panels of ≤ 8.
    pub fn spmv_rows_into_multi(
        &self,
        x: &[f64],
        k: usize,
        r0: usize,
        r1: usize,
        buf: &mut [f64],
        lo: usize,
    ) {
        assert!(k >= 1 && r1 <= self.nrows && x.len() == self.ncols * k);
        debug_assert!(buf.len() >= (r1 - lo) * k);
        let mut c0 = 0usize;
        while c0 < k {
            let kc = (k - c0).min(8);
            for i in r0..r1 {
                let mut t = [0.0f64; 8];
                for kk in self.row_range(i) {
                    let xj = self.ja[kk] as usize * k + c0;
                    let av = self.a[kk];
                    for c in 0..kc {
                        t[c] += av * x[xj + c];
                    }
                }
                let yi = (i - lo) * k + c0;
                for c in 0..kc {
                    buf[yi + c] += t[c];
                }
            }
            c0 += kc;
        }
    }

    /// yᵀ = Aᵀ x — requires a column-order sweep; expensive for CSR (the
    /// §5 contrast with CSRC's free transpose).
    pub fn spmv_t(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.nrows);
        debug_assert_eq!(y.len(), self.ncols);
        y.fill(0.0);
        for i in 0..self.nrows {
            let xi = x[i];
            for k in self.row_range(i) {
                y[self.ja[k] as usize] += self.a[k] * xi;
            }
        }
    }

    /// Value lookup (binary search within the row).
    pub fn get(&self, i: usize, j: usize) -> Option<f64> {
        let r = self.row_range(i);
        let row = &self.ja[r.clone()];
        row.binary_search(&(j as u32)).ok().map(|p| self.a[r.start + p])
    }

    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::with_capacity(self.nrows, self.ncols, self.nnz());
        for i in 0..self.nrows {
            for k in self.row_range(i) {
                coo.push(i, self.ja[k] as usize, self.a[k]);
            }
        }
        coo
    }

    /// Is the non-zero *pattern* symmetric?
    pub fn is_structurally_symmetric(&self) -> bool {
        if self.nrows != self.ncols {
            return false;
        }
        (0..self.nrows).all(|i| {
            self.row_range(i).all(|k| {
                let j = self.ja[k] as usize;
                j == i || self.get(j, i).is_some()
            })
        })
    }

    /// Is the matrix numerically symmetric?
    pub fn is_numerically_symmetric(&self, tol: f64) -> bool {
        self.is_structurally_symmetric()
            && (0..self.nrows).all(|i| {
                self.row_range(i).all(|k| {
                    let j = self.ja[k] as usize;
                    j == i || (self.get(j, i).unwrap() - self.a[k]).abs() <= tol
                })
            })
    }

    /// Working-set bytes of one SpMV: all arrays + x + y (Table 1's ws).
    pub fn working_set_bytes(&self) -> usize {
        (self.ia.len() + self.ja.len()) * 4
            + self.a.len() * 8
            + (self.ncols + self.nrows) * 8
    }

    /// Flops of one SpMV (multiply+add counted separately): 2·nnz (§4.1).
    pub fn flops(&self) -> usize {
        2 * self.nnz()
    }

    /// The matrix renumbered symmetrically by `perm` (B = P A Pᵀ) —
    /// valid for any square CSR, no symmetry needed.
    pub fn permuted(&self, perm: &crate::reorder::Permutation) -> Csr {
        assert_eq!(self.nrows, self.ncols, "symmetric permutation needs a square matrix");
        assert_eq!(perm.len(), self.nrows, "permutation length mismatch");
        let mut coo = Coo::with_capacity(self.nrows, self.ncols, self.nnz());
        for i in 0..self.nrows {
            for k in self.row_range(i) {
                coo.push(perm.new_of(i), perm.new_of(self.ja[k] as usize), self.a[k]);
            }
        }
        coo.compact();
        Csr::from_coo(&coo)
    }
}

impl SpmvKernel for Csr {
    fn dim(&self) -> usize {
        assert_eq!(self.nrows, self.ncols, "SpmvKernel needs a square CSR");
        self.nrows
    }

    fn row_work(&self, i: usize) -> usize {
        1 + self.row_range(i).len()
    }

    fn row_write_lo(&self, i: usize) -> usize {
        i // a CSR row sweep writes y_i only
    }

    fn scatter_targets(&self, _i: usize, _visit: &mut dyn FnMut(usize)) {
        // No scatters: CSR row sweeps are already race-free.
    }

    fn sweep_rows_into(&self, x: &[f64], r0: usize, r1: usize, buf: &mut [f64], lo: usize) {
        self.spmv_rows_into(x, r0, r1, buf, lo);
    }

    unsafe fn sweep_row_shared(&self, x: &[f64], i: usize, y: *mut f64) {
        let mut t = 0.0;
        for k in self.row_range(i) {
            t += self.a[k] * x[self.ja[k] as usize];
        }
        *y.add(i) += t;
    }

    fn sweep_row_contribs(&self, x: &[f64], i: usize, emit: &mut dyn FnMut(usize, f64)) {
        let mut t = 0.0;
        for k in self.row_range(i) {
            t += self.a[k] * x[self.ja[k] as usize];
        }
        emit(i, t);
    }

    fn sweep_full(&self, x: &[f64], y: &mut [f64]) {
        self.spmv(x, y);
    }

    fn sweep_rows_into_multi(
        &self,
        x: &[f64],
        k: usize,
        r0: usize,
        r1: usize,
        buf: &mut [f64],
        lo: usize,
    ) {
        self.spmv_rows_into_multi(x, k, r0, r1, buf, lo);
    }

    unsafe fn sweep_row_shared_multi(&self, x: &[f64], k: usize, i: usize, y: *mut f64) {
        let mut c0 = 0usize;
        while c0 < k {
            let kc = (k - c0).min(8);
            let mut t = [0.0f64; 8];
            for kk in self.row_range(i) {
                let xj = self.ja[kk] as usize * k + c0;
                let av = self.a[kk];
                for c in 0..kc {
                    t[c] += av * x[xj + c];
                }
            }
            for c in 0..kc {
                *y.add(i * k + c0 + c) += t[c];
            }
            c0 += kc;
        }
    }

    fn sweep_row_contribs_multi(
        &self,
        x: &[f64],
        k: usize,
        i: usize,
        emit: &mut dyn FnMut(usize, f64),
    ) {
        let mut c0 = 0usize;
        while c0 < k {
            let kc = (k - c0).min(8);
            let mut t = [0.0f64; 8];
            for kk in self.row_range(i) {
                let xj = self.ja[kk] as usize * k + c0;
                let av = self.a[kk];
                for c in 0..kc {
                    t[c] += av * x[xj + c];
                }
            }
            for c in 0..kc {
                emit(i * k + c0 + c, t[c]);
            }
            c0 += kc;
        }
    }

    fn kernel_name(&self) -> &'static str {
        "csr"
    }

    fn permuted(
        &self,
        perm: &crate::reorder::Permutation,
    ) -> Option<std::sync::Arc<dyn SpmvKernel>> {
        Some(std::sync::Arc::new(Csr::permuted(self, perm)))
    }
}

impl LinOp for Csr {
    fn dim(&self) -> usize {
        assert_eq!(self.nrows, self.ncols);
        self.nrows
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.spmv(x, y)
    }
    fn apply_t(&self, x: &[f64], y: &mut [f64]) -> Result<(), String> {
        self.spmv_t(x, y);
        Ok(())
    }
    fn diagonal(&self) -> Option<Vec<f64>> {
        Some((0..self.nrows).map(|i| self.get(i, i).unwrap_or(0.0)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn example() -> Csr {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [4, 0, 5]]
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 1.0);
        coo.push(0, 2, 2.0);
        coo.push(1, 1, 3.0);
        coo.push(2, 0, 4.0);
        coo.push(2, 2, 5.0);
        coo.compact();
        Csr::from_coo(&coo)
    }

    #[test]
    fn spmv_matches_hand_computation() {
        let a = example();
        let x = [1.0, 2.0, 3.0];
        let mut y = [0.0; 3];
        a.spmv(&x, &mut y);
        assert_eq!(y, [7.0, 6.0, 19.0]);
    }

    #[test]
    fn spmv_t_matches_dense_transpose() {
        let a = example();
        let x = [1.0, 2.0, 3.0];
        let mut y = [0.0; 3];
        a.spmv_t(&x, &mut y);
        assert_eq!(y, [13.0, 6.0, 17.0]); // Aᵀx
    }

    #[test]
    fn get_and_diagonal() {
        let a = example();
        assert_eq!(a.get(0, 2), Some(2.0));
        assert_eq!(a.get(0, 1), None);
        assert_eq!(a.diagonal().unwrap(), vec![1.0, 3.0, 5.0]);
    }

    #[test]
    fn structural_symmetry() {
        let a = example();
        assert!(a.is_structurally_symmetric()); // (0,2)&(2,0) both present
        assert!(!a.is_numerically_symmetric(1e-12));
    }

    #[test]
    fn coo_roundtrip() {
        let a = example();
        let back = Csr::from_coo(&{
            let mut c = a.to_coo();
            c.compact();
            c
        });
        assert_eq!(a.ia, back.ia);
        assert_eq!(a.ja, back.ja);
        assert_eq!(a.a, back.a);
    }

    #[test]
    fn random_spmv_vs_dense() {
        let mut rng = Rng::new(3);
        let coo = Coo::random_structurally_symmetric(40, 5, false, &mut rng);
        let a = Csr::from_coo(&coo);
        let dense = coo.to_dense();
        let x: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; 40];
        a.spmv(&x, &mut y);
        for i in 0..40 {
            let want: f64 = (0..40).map(|j| dense[i][j] * x[j]).sum();
            assert!((y[i] - want).abs() < 1e-9 * (1.0 + want.abs()), "row {i}");
        }
    }

    #[test]
    fn working_set_grows_with_nnz() {
        let a = example();
        assert!(a.working_set_bytes() > 0);
        assert_eq!(a.flops(), 10);
    }
}
