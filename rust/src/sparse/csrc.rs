//! CSRC — compressed sparse row-column (the paper's format, §2).
//!
//! For a structurally symmetric n×n matrix A = A_D + A_L + A_U:
//!
//! * `ad(n)`   — diagonal,
//! * `al(k)`   — strict lower triangle, row-wise (CSR of A_L),
//! * `au(k)`   — strict upper triangle, **column-wise** (CSC of A_U), i.e.
//!   `au[k]` is the transpose mirror `a_ji` of `al[k] = a_ij`,
//! * `ia(n+1)`, `ja(k)` — one shared index structure, k = (nnz − n)/2.
//!
//! One sweep of row i computes both `y_i += a_ij x_j` and
//! `y_j += a_ji x_i` (Fig. 2a of the paper) — that second scatter is what
//! the parallel engines in `parallel/` must make thread-safe.

use super::{Coo, Csr, Ell, LinOp, SpmvKernel};

#[derive(Clone, Debug)]
pub struct Csrc {
    pub n: usize,
    pub ad: Vec<f64>,
    pub al: Vec<f64>,
    pub au: Vec<f64>,
    pub ia: Vec<u32>,
    pub ja: Vec<u32>,
    /// Detected at construction: al[k] == au[k] for all k. Enables the
    /// one-load-fewer specialization of §2.2.
    pub numeric_symmetric: bool,
}

/// Error for construction from a pattern that is not structurally
/// symmetric or lacks a full diagonal.
#[derive(Debug, PartialEq)]
pub enum CsrcError {
    NotSquare { nrows: usize, ncols: usize },
    MissingMirror { i: usize, j: usize },
    MissingDiagonal { i: usize },
    /// `update_values` was handed value arrays whose lengths do not match
    /// this matrix's (n, k) shape.
    ValueLengthMismatch { want_n: usize, want_k: usize, got_ad: usize, got_al: usize, got_au: usize },
    /// `update_values_from` was handed a matrix with a different index
    /// structure (pattern fingerprints differ) — an in-place value swap
    /// would silently mis-assign entries, so it is refused.
    PatternMismatch { want: u64, got: u64 },
}

impl std::fmt::Display for CsrcError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CsrcError::NotSquare { nrows, ncols } => {
                write!(f, "CSRC needs a square matrix, got {nrows}x{ncols} (use CsrcRect)")
            }
            CsrcError::MissingMirror { i, j } => {
                write!(f, "pattern not structurally symmetric: ({i},{j}) has no ({j},{i})")
            }
            CsrcError::MissingDiagonal { i } => {
                write!(f, "CSRC stores a dense diagonal but a[{i}][{i}] is structurally zero")
            }
            CsrcError::ValueLengthMismatch { want_n, want_k, got_ad, got_al, got_au } => {
                write!(
                    f,
                    "value update shape mismatch: matrix wants ad({want_n})/al({want_k})/au({want_k}), \
                     got ad({got_ad})/al({got_al})/au({got_au})"
                )
            }
            CsrcError::PatternMismatch { want, got } => {
                write!(
                    f,
                    "value update refused: pattern fingerprint {got:#018x} does not match \
                     this matrix's {want:#018x} (re-register instead)"
                )
            }
        }
    }
}

impl std::error::Error for CsrcError {}

impl Csrc {
    /// Build from CSR in O(nnz) with the transpose-cursor sweep: row i's
    /// lower entry (i, j) needs the mirror value a_ji, which is the next
    /// yet-unconsumed upper entry of row j — rows are column-sorted and i
    /// ascends, so one cursor per row suffices.
    pub fn from_csr(csr: &Csr) -> Result<Csrc, CsrcError> {
        if csr.nrows != csr.ncols {
            return Err(CsrcError::NotSquare { nrows: csr.nrows, ncols: csr.ncols });
        }
        let n = csr.nrows;
        let mut ad = vec![0.0; n];
        let mut ia = Vec::with_capacity(n + 1);
        let mut ja = Vec::new();
        let mut al = Vec::new();
        let mut au = Vec::new();
        // up[j]: cursor into row j pointing at the first not-yet-mirrored
        // strict-upper entry (column > j).
        let mut up: Vec<usize> = (0..n)
            .map(|j| {
                let r = csr.row_range(j);
                let row = &csr.ja[r.clone()];
                r.start + row.partition_point(|&c| (c as usize) <= j)
            })
            .collect();
        let mut have_diag = vec![false; n];
        for i in 0..n {
            for k in csr.row_range(i) {
                if csr.ja[k] as usize == i {
                    have_diag[i] = true;
                }
            }
        }
        if let Some(i) = have_diag.iter().position(|&h| !h) {
            return Err(CsrcError::MissingDiagonal { i });
        }
        ia.push(0u32);
        for i in 0..n {
            for k in csr.row_range(i) {
                let j = csr.ja[k] as usize;
                if j > i {
                    break; // row is sorted; rest is upper, handled via mirrors
                }
                if j == i {
                    ad[i] = csr.a[k];
                    continue;
                }
                // Lower entry (i, j): advance row j's upper cursor to col i.
                let r_end = csr.row_range(j).end;
                while up[j] < r_end && (csr.ja[up[j]] as usize) < i {
                    // A strict-upper entry of row j whose mirror was never
                    // seen as a lower entry => pattern not symmetric.
                    return Err(CsrcError::MissingMirror {
                        i: csr.ja[up[j]] as usize,
                        j,
                    });
                }
                if up[j] >= r_end || csr.ja[up[j]] as usize != i {
                    return Err(CsrcError::MissingMirror { i, j });
                }
                ja.push(j as u32);
                al.push(csr.a[k]);
                au.push(csr.a[up[j]]);
                up[j] += 1;
            }
            ia.push(ja.len() as u32);
        }
        // Any unconsumed upper entries mean missing lower mirrors.
        for j in 0..n {
            if up[j] != csr.row_range(j).end {
                return Err(CsrcError::MissingMirror { i: csr.ja[up[j]] as usize, j });
            }
        }
        let numeric_symmetric =
            al.iter().zip(&au).all(|(l, u)| (l - u).abs() <= 1e-14 * l.abs().max(u.abs()));
        Ok(Csrc { n, ad, al, au, ia, ja, numeric_symmetric })
    }

    pub fn from_coo(coo: &Coo) -> Result<Csrc, CsrcError> {
        Csrc::from_csr(&Csr::from_coo(coo))
    }

    /// Off-diagonal pair count k = (nnz − n) / 2.
    pub fn k(&self) -> usize {
        self.ja.len()
    }

    /// Total non-zeros of the represented matrix (diag + 2k).
    pub fn nnz(&self) -> usize {
        self.n + 2 * self.k()
    }

    #[inline]
    pub fn row_range(&self, i: usize) -> std::ops::Range<usize> {
        self.ia[i] as usize..self.ia[i + 1] as usize
    }

    /// FNV-1a over the *index structure only* (n, ia, ja) — values are
    /// excluded. Two matrices share a pattern fingerprint exactly when an
    /// in-place value swap between them is well defined: successive FEM
    /// assemblies on one mesh hash identically, a remeshed matrix does
    /// not. (Distinct from `tuner::features::fingerprint`, which also
    /// mixes in per-row work for decision-cache keying; this one is the
    /// update-path guard.)
    pub fn pattern_fingerprint(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf29ce484222325;
        const FNV_PRIME: u64 = 0x100000001b3;
        let mut h = FNV_OFFSET;
        let mut mix = |v: u64| {
            for b in v.to_le_bytes() {
                h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
            }
        };
        mix(self.n as u64);
        for &p in &self.ia {
            mix(p as u64);
        }
        for &j in &self.ja {
            mix(j as u64);
        }
        h
    }

    /// Replace the numeric values in place, keeping the index structure,
    /// and re-detect numeric symmetry. The in-place path of FEM
    /// time-stepping: same pattern, new values, so every pattern-derived
    /// artifact (plan, coloring, RCM ordering, tuned decision) stays
    /// valid. Never panics on bad input — a shape mismatch is a typed
    /// error and the matrix is left untouched.
    pub fn update_values(&mut self, ad: &[f64], al: &[f64], au: &[f64]) -> Result<(), CsrcError> {
        let k = self.k();
        if ad.len() != self.n || al.len() != k || au.len() != k {
            return Err(CsrcError::ValueLengthMismatch {
                want_n: self.n,
                want_k: k,
                got_ad: ad.len(),
                got_al: al.len(),
                got_au: au.len(),
            });
        }
        self.ad.copy_from_slice(ad);
        self.al.copy_from_slice(al);
        self.au.copy_from_slice(au);
        self.numeric_symmetric = self
            .al
            .iter()
            .zip(&self.au)
            .all(|(l, u)| (l - u).abs() <= 1e-14 * l.abs().max(u.abs()));
        Ok(())
    }

    /// Pattern-fingerprint-checked value swap from another matrix: the
    /// form service-level `update_values` uses. Refuses (typed error, no
    /// panic, `self` untouched) when the index structures differ.
    pub fn update_values_from(&mut self, other: &Csrc) -> Result<(), CsrcError> {
        let want = self.pattern_fingerprint();
        let got = other.pattern_fingerprint();
        if want != got {
            return Err(CsrcError::PatternMismatch { want, got });
        }
        self.update_values(&other.ad, &other.al, &other.au)
    }

    /// Sequential SpMV, Fig. 2(a) of the paper: one sweep updates y_i with
    /// the lower entries *and* scatters the mirrored upper contributions.
    ///
    /// Hot path: unchecked indexing inside the k-loop (EXPERIMENTS.md
    /// §Perf). Safety: `ia`/`ja` are construction-validated (every ja[k]
    /// < i < n, ia ascending, ia[n] == k-arrays' length) and the index
    /// arrays are never mutated after construction (`update_values`
    /// replaces values only, keeping their lengths).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.n);
        unsafe {
            for i in 0..self.n {
                let xi = *x.get_unchecked(i);
                let mut t = self.ad.get_unchecked(i) * xi;
                let start = *self.ia.get_unchecked(i) as usize;
                let end = *self.ia.get_unchecked(i + 1) as usize;
                for k in start..end {
                    let j = *self.ja.get_unchecked(k) as usize;
                    t += self.al.get_unchecked(k) * x.get_unchecked(j);
                    *y.get_unchecked_mut(j) += self.au.get_unchecked(k) * xi;
                }
                *y.get_unchecked_mut(i) += t;
            }
        }
    }

    /// `spmv` assuming y is already zeroed — matches the Fig. 2(a) listing
    /// (which writes `y(i) = t`). The variant above accumulates so the
    /// parallel engines can reuse it on live buffers.
    pub fn spmv_into_zeroed(&self, x: &[f64], y: &mut [f64]) {
        y.fill(0.0);
        self.spmv(x, y);
    }

    /// Numerically symmetric specialization (§2.2: one fewer load stream —
    /// `au` is never touched).
    pub fn spmv_sym(&self, x: &[f64], y: &mut [f64]) {
        debug_assert!(self.numeric_symmetric);
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.n);
        // Safety: same construction invariants as `spmv`.
        unsafe {
            for i in 0..self.n {
                let xi = *x.get_unchecked(i);
                let mut t = self.ad.get_unchecked(i) * xi;
                let start = *self.ia.get_unchecked(i) as usize;
                let end = *self.ia.get_unchecked(i + 1) as usize;
                for k in start..end {
                    let j = *self.ja.get_unchecked(k) as usize;
                    let v = *self.al.get_unchecked(k);
                    t += v * x.get_unchecked(j);
                    *y.get_unchecked_mut(j) += v * xi;
                }
                *y.get_unchecked_mut(i) += t;
            }
        }
    }

    /// Sequential SpMV over a row block [r0, r1) into an arbitrary buffer —
    /// the building block every parallel engine shares. Scatters go to
    /// `buf[ja[k] - lo]` where `lo` offsets the buffer (0 for full-length).
    #[inline]
    pub fn spmv_rows_into(&self, x: &[f64], r0: usize, r1: usize, buf: &mut [f64], lo: usize) {
        assert!(r1 <= self.n && x.len() == self.n);
        // Safety: construction invariants (see `spmv`) plus the engines'
        // guarantee that `buf` covers the block's effective range
        // [min ja, r1) offset by `lo` (asserted in debug builds below).
        debug_assert!(buf.len() >= r1 - lo);
        unsafe {
            for i in r0..r1 {
                let xi = *x.get_unchecked(i);
                let mut t = self.ad.get_unchecked(i) * xi;
                let start = *self.ia.get_unchecked(i) as usize;
                let end = *self.ia.get_unchecked(i + 1) as usize;
                for k in start..end {
                    let j = *self.ja.get_unchecked(k) as usize;
                    t += self.al.get_unchecked(k) * x.get_unchecked(j);
                    debug_assert!(j >= lo && j - lo < buf.len());
                    *buf.get_unchecked_mut(j - lo) += self.au.get_unchecked(k) * xi;
                }
                *buf.get_unchecked_mut(i - lo) += t;
            }
        }
    }

    /// Multi-vector (SpMM) row-block sweep: the k-wide analogue of
    /// [`Csrc::spmv_rows_into`] over row-major panels (`x[j*k + c]`,
    /// `buf[(j - lo)*k + c]`). One pass over `ia`/`ja`/`al`/`au` serves
    /// all k columns — the matrix (values *and* index structure) is read
    /// once instead of k times, which is the whole blocked-product win
    /// on a bandwidth-bound sweep. Columns are processed in register
    /// panels of ≤ 8 so the per-row accumulator stays on the stack for
    /// any k.
    pub fn spmv_rows_into_multi(
        &self,
        x: &[f64],
        k: usize,
        r0: usize,
        r1: usize,
        buf: &mut [f64],
        lo: usize,
    ) {
        assert!(k >= 1 && r1 <= self.n && x.len() == self.n * k);
        debug_assert!(buf.len() >= (r1 - lo) * k);
        let mut c0 = 0usize;
        while c0 < k {
            let kc = (k - c0).min(8);
            // Safety: same construction invariants as `spmv` (every
            // ja[kk] < i < n); panel offsets stay inside x (len n·k) and
            // buf (covers rows [lo, r1) × k, asserted above and checked
            // per-scatter in debug builds).
            unsafe {
                for i in r0..r1 {
                    let xi = i * k + c0;
                    let adi = *self.ad.get_unchecked(i);
                    let mut t = [0.0f64; 8];
                    for c in 0..kc {
                        t[c] = adi * *x.get_unchecked(xi + c);
                    }
                    let start = *self.ia.get_unchecked(i) as usize;
                    let end = *self.ia.get_unchecked(i + 1) as usize;
                    for kk in start..end {
                        let j = *self.ja.get_unchecked(kk) as usize;
                        let alv = *self.al.get_unchecked(kk);
                        let auv = *self.au.get_unchecked(kk);
                        let xj = j * k + c0;
                        debug_assert!(j >= lo && (j - lo) * k + c0 + kc <= buf.len());
                        let yj = (j - lo) * k + c0;
                        for c in 0..kc {
                            t[c] += alv * *x.get_unchecked(xj + c);
                            *buf.get_unchecked_mut(yj + c) += auv * *x.get_unchecked(xi + c);
                        }
                    }
                    let yi = (i - lo) * k + c0;
                    for c in 0..kc {
                        *buf.get_unchecked_mut(yi + c) += t[c];
                    }
                }
            }
            c0 += kc;
        }
    }

    /// Full k-wide product into a row-major panel, `y` fully
    /// overwritten — the sequential SpMM baseline.
    pub fn spmv_panel(&self, x: &[f64], y: &mut [f64], k: usize) {
        assert_eq!(y.len(), self.n * k);
        y.fill(0.0);
        self.spmv_rows_into_multi(x, k, 0, self.n, y, 0);
    }

    /// y = Aᵀ x — the paper's §5 point: swap al and au, identical cost.
    ///
    /// Same unchecked-hot-loop shape as `spmv` — `bicg` pays this every
    /// iteration, so the transpose must not lag the forward product on
    /// bounds checks. Safety: identical argument to `spmv` — the sweep
    /// touches exactly the same `ia`/`ja`/`ad`/`al`/`au` indices (only
    /// the roles of `al` and `au` swap), all construction-validated and
    /// immutable after construction.
    pub fn spmv_t(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.n);
        unsafe {
            for i in 0..self.n {
                let xi = *x.get_unchecked(i);
                let mut t = self.ad.get_unchecked(i) * xi;
                let start = *self.ia.get_unchecked(i) as usize;
                let end = *self.ia.get_unchecked(i + 1) as usize;
                for k in start..end {
                    let j = *self.ja.get_unchecked(k) as usize;
                    t += self.au.get_unchecked(k) * x.get_unchecked(j); // roles swapped
                    *y.get_unchecked_mut(j) += self.al.get_unchecked(k) * xi;
                }
                *y.get_unchecked_mut(i) += t;
            }
        }
    }

    /// Reconstruct the full CSR (tests, format comparisons).
    pub fn to_csr(&self) -> Csr {
        let mut coo = Coo::with_capacity(self.n, self.n, self.nnz());
        for i in 0..self.n {
            coo.push(i, i, self.ad[i]);
            for k in self.row_range(i) {
                let j = self.ja[k] as usize;
                coo.push(i, j, self.al[k]);
                coo.push(j, i, self.au[k]);
            }
        }
        coo.compact();
        Csr::from_coo(&coo)
    }

    /// Pad to the ELL layout consumed by the Pallas kernel / XLA runtime:
    /// (n_pad, w) with padding slots pointing at the row itself with zero
    /// values. Returns None if any row exceeds `w` or n exceeds `n_pad`.
    pub fn to_ell(&self, n_pad: usize, w: usize) -> Option<Ell> {
        if self.n > n_pad {
            return None;
        }
        if (0..self.n).any(|i| self.row_range(i).len() > w) {
            return None;
        }
        let mut ell = Ell::empty(n_pad, w);
        for i in 0..self.n {
            ell.ad[i] = self.ad[i] as f32;
            for (slot, k) in self.row_range(i).enumerate() {
                ell.al[i * w + slot] = self.al[k] as f32;
                ell.au[i * w + slot] = self.au[k] as f32;
                ell.ja[i * w + slot] = self.ja[k] as i32;
            }
            for slot in self.row_range(i).len()..w {
                ell.ja[i * w + slot] = i as i32;
            }
        }
        for i in self.n..n_pad {
            ell.ad[i] = 0.0;
            for slot in 0..w {
                ell.ja[i * w + slot] = i as i32;
            }
        }
        Some(ell)
    }

    /// Max row width of the lower pattern (for ELL sizing).
    pub fn max_row_width(&self) -> usize {
        (0..self.n).map(|i| self.row_range(i).len()).max().unwrap_or(0)
    }

    /// Half-bandwidth: max over lower entries of (i − j).
    pub fn half_bandwidth(&self) -> usize {
        let mut bw = 0usize;
        for i in 0..self.n {
            for k in self.row_range(i) {
                bw = bw.max(i - self.ja[k] as usize);
            }
        }
        bw
    }

    /// Working-set bytes of one SpMV (Table 1's ws column): ad + al + au +
    /// ia + ja + x + y.
    pub fn working_set_bytes(&self) -> usize {
        self.ad.len() * 8
            + (self.al.len() + self.au.len()) * 8
            + (self.ia.len() + self.ja.len()) * 4
            + 2 * self.n * 8
    }

    /// Working-set bytes of one *parallel* local-buffers product under
    /// `plan`: the sequential working set plus the p private scatter
    /// buffers. With the plan's effective ranges the buffers are
    /// *windowed* ([`crate::parallel::LocalBuffersEngine`]), so this
    /// counts only the window bytes — Table-1-style reports and the
    /// tuner's bandwidth features were under-counting the local-buffers
    /// engines by up to `p·n·8` before this.
    pub fn working_set_bytes_parallel(&self, plan: &crate::plan::SpmvPlan) -> usize {
        self.working_set_bytes_parallel_multi(plan, 1)
    }

    /// k-wide working set: the matrix arrays are read once regardless of
    /// k (the point of the blocked product), while x, y and the scatter
    /// windows widen to k columns ([`crate::plan::SpmvPlan::windowed_buffer_bytes`]).
    pub fn working_set_bytes_parallel_multi(
        &self,
        plan: &crate::plan::SpmvPlan,
        k: usize,
    ) -> usize {
        assert_eq!(plan.n, self.n, "plan built for a different matrix");
        let vectors = 2 * self.n * 8 * (k - 1); // x/y beyond the k=1 base
        // Single thread writes y directly (windowed_buffer_bytes is 0).
        self.working_set_bytes() + vectors + plan.windowed_buffer_bytes(k)
    }

    /// The matrix renumbered by `perm`: B = P A Pᵀ with
    /// `B[new_i][new_j] = A[old_i][old_j]`. A symmetric permutation
    /// preserves structural symmetry and the diagonal, so the result is
    /// always a valid CSRC. Built via COO (O(nnz log nnz)) — reordering
    /// is one-time analysis, not a hot path.
    pub fn permuted(&self, perm: &crate::reorder::Permutation) -> Csrc {
        assert_eq!(perm.len(), self.n, "permutation length mismatch");
        let csr = self.to_csr();
        let mut coo = Coo::with_capacity(self.n, self.n, self.nnz());
        for i in 0..self.n {
            for k in csr.row_range(i) {
                coo.push(perm.new_of(i), perm.new_of(csr.ja[k] as usize), csr.a[k]);
            }
        }
        coo.compact();
        Csrc::from_coo(&coo).expect("symmetric permutation preserves structural symmetry")
    }

    /// Flops of one SpMV: n multiplies + (nnz − n) multiply-adds ≈ 2·nnz − n
    /// on machines without FMA (§4.1).
    pub fn flops(&self) -> usize {
        2 * self.nnz() - self.n
    }

    /// Load instructions of one SpMV: (5/2)·nnz − (1/2)·n (§4.1), vs 3·nnz
    /// for CSR — the bandwidth-mitigation argument.
    pub fn loads(&self) -> usize {
        (5 * self.nnz() - self.n) / 2
    }
}

impl SpmvKernel for Csrc {
    fn dim(&self) -> usize {
        self.n
    }

    /// Diagonal multiply plus two updates per stored lower entry (gather
    /// into y_i, scatter to y_j) — the §3.1 nnz-guided weight.
    fn row_work(&self, i: usize) -> usize {
        1 + 2 * self.row_range(i).len()
    }

    fn row_write_lo(&self, i: usize) -> usize {
        let mut lo = i;
        for k in self.row_range(i) {
            lo = lo.min(self.ja[k] as usize);
        }
        lo
    }

    fn scatter_targets(&self, i: usize, visit: &mut dyn FnMut(usize)) {
        for k in self.row_range(i) {
            visit(self.ja[k] as usize);
        }
    }

    fn sweep_rows_into(&self, x: &[f64], r0: usize, r1: usize, buf: &mut [f64], lo: usize) {
        self.spmv_rows_into(x, r0, r1, buf, lo);
    }

    unsafe fn sweep_row_shared(&self, x: &[f64], i: usize, y: *mut f64) {
        let xi = x[i];
        let mut acc = self.ad[i] * xi;
        for k in self.row_range(i) {
            let j = self.ja[k] as usize;
            acc += self.al[k] * x[j];
            *y.add(j) += self.au[k] * xi;
        }
        *y.add(i) += acc;
    }

    fn sweep_row_contribs(&self, x: &[f64], i: usize, emit: &mut dyn FnMut(usize, f64)) {
        let xi = x[i];
        let mut acc = self.ad[i] * xi;
        for k in self.row_range(i) {
            let j = self.ja[k] as usize;
            acc += self.al[k] * x[j];
            emit(j, self.au[k] * xi);
        }
        emit(i, acc);
    }

    fn sweep_full(&self, x: &[f64], y: &mut [f64]) {
        self.spmv_into_zeroed(x, y);
    }

    fn sweep_rows_into_multi(
        &self,
        x: &[f64],
        k: usize,
        r0: usize,
        r1: usize,
        buf: &mut [f64],
        lo: usize,
    ) {
        self.spmv_rows_into_multi(x, k, r0, r1, buf, lo);
    }

    fn sweep_full_multi(&self, x: &[f64], y: &mut [f64], k: usize) {
        self.spmv_panel(x, y, k);
    }

    unsafe fn sweep_row_shared_multi(&self, x: &[f64], k: usize, i: usize, y: *mut f64) {
        let mut c0 = 0usize;
        while c0 < k {
            let kc = (k - c0).min(8);
            let xi = i * k + c0;
            let mut t = [0.0f64; 8];
            for c in 0..kc {
                t[c] = self.ad[i] * x[xi + c];
            }
            for kk in self.row_range(i) {
                let j = self.ja[kk] as usize;
                let (alv, auv) = (self.al[kk], self.au[kk]);
                let xj = j * k + c0;
                for c in 0..kc {
                    t[c] += alv * x[xj + c];
                    *y.add(xj + c) += auv * x[xi + c];
                }
            }
            for c in 0..kc {
                *y.add(xi + c) += t[c];
            }
            c0 += kc;
        }
    }

    fn sweep_row_contribs_multi(
        &self,
        x: &[f64],
        k: usize,
        i: usize,
        emit: &mut dyn FnMut(usize, f64),
    ) {
        let mut c0 = 0usize;
        while c0 < k {
            let kc = (k - c0).min(8);
            let xi = i * k + c0;
            let mut t = [0.0f64; 8];
            for c in 0..kc {
                t[c] = self.ad[i] * x[xi + c];
            }
            for kk in self.row_range(i) {
                let j = self.ja[kk] as usize;
                let (alv, auv) = (self.al[kk], self.au[kk]);
                let xj = j * k + c0;
                for c in 0..kc {
                    t[c] += alv * x[xj + c];
                    emit(xj + c, auv * x[xi + c]);
                }
            }
            for c in 0..kc {
                emit(xi + c, t[c]);
            }
            c0 += kc;
        }
    }

    fn kernel_name(&self) -> &'static str {
        "csrc"
    }

    fn permuted(
        &self,
        perm: &crate::reorder::Permutation,
    ) -> Option<std::sync::Arc<dyn SpmvKernel>> {
        Some(std::sync::Arc::new(Csrc::permuted(self, perm)))
    }
}

impl LinOp for Csrc {
    fn dim(&self) -> usize {
        self.n
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.spmv_into_zeroed(x, y)
    }
    fn apply_t(&self, x: &[f64], y: &mut [f64]) -> Result<(), String> {
        y.fill(0.0);
        self.spmv_t(x, y);
        Ok(())
    }
    fn apply_multi(&self, x: &[f64], y: &mut [f64], k: usize) {
        self.spmv_panel(x, y, k);
    }
    fn diagonal(&self) -> Option<Vec<f64>> {
        Some(self.ad.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{propcheck, Rng};

    /// The paper's Figure 1 example: a 9×9 non-symmetric matrix with a
    /// structurally symmetric pattern, 33 non-zeros.
    pub fn paper_fig1() -> Coo {
        let mut coo = Coo::new(9, 9);
        // Diagonal.
        for i in 0..9 {
            coo.push(i, i, (i + 1) as f64);
        }
        // Strict lower pattern (i, j) with j < i; mirrors added with
        // different values => structurally but not numerically symmetric.
        let lower = [
            (1, 0), (3, 1), (4, 0), (4, 3), (5, 2), (6, 0), (6, 4),
            (7, 3), (7, 5), (8, 2), (8, 6), (8, 7),
        ];
        for (t, &(i, j)) in lower.iter().enumerate() {
            coo.push(i, j, 10.0 + t as f64);
            coo.push(j, i, 30.0 + t as f64);
        }
        coo.compact();
        coo
    }

    #[test]
    fn fig1_shape() {
        let coo = paper_fig1();
        assert_eq!(coo.nnz(), 33);
        assert!(coo.is_structurally_symmetric());
        let m = Csrc::from_coo(&coo).unwrap();
        assert_eq!(m.n, 9);
        assert_eq!(m.k(), 12); // (33 - 9) / 2
        assert_eq!(m.nnz(), 33);
        assert!(!m.numeric_symmetric);
    }

    #[test]
    fn spmv_matches_dense_on_fig1() {
        let coo = paper_fig1();
        let m = Csrc::from_coo(&coo).unwrap();
        let dense = coo.to_dense();
        let mut rng = Rng::new(1);
        let x: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; 9];
        m.spmv_into_zeroed(&x, &mut y);
        for i in 0..9 {
            let want: f64 = (0..9).map(|j| dense[i][j] * x[j]).sum();
            assert!((y[i] - want).abs() < 1e-12, "row {i}: {} vs {want}", y[i]);
        }
    }

    #[test]
    fn rejects_asymmetric_pattern() {
        let mut coo = Coo::new(3, 3);
        for i in 0..3 {
            coo.push(i, i, 1.0);
        }
        coo.push(2, 0, 5.0); // no (0,2) mirror
        coo.compact();
        assert_eq!(
            Csrc::from_coo(&coo).unwrap_err(),
            CsrcError::MissingMirror { i: 2, j: 0 }
        );
    }

    #[test]
    fn rejects_missing_diagonal() {
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, 2.0);
        coo.push(1, 0, 3.0);
        coo.compact();
        assert_eq!(Csrc::from_coo(&coo).unwrap_err(), CsrcError::MissingDiagonal { i: 1 });
    }

    #[test]
    fn rejects_rectangular() {
        let coo = Coo::new(2, 3);
        assert!(matches!(Csrc::from_coo(&coo), Err(CsrcError::NotSquare { .. })));
    }

    #[test]
    fn transpose_is_free_and_correct() {
        let coo = paper_fig1();
        let m = Csrc::from_coo(&coo).unwrap();
        let dense = coo.to_dense();
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; 9];
        m.apply_t(&x, &mut y).unwrap();
        for j in 0..9 {
            let want: f64 = (0..9).map(|i| dense[i][j] * x[i]).sum();
            assert!((y[j] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn symmetric_kernel_matches_general() {
        let mut rng = Rng::new(3);
        let coo = Coo::random_structurally_symmetric(60, 5, true, &mut rng);
        let m = Csrc::from_coo(&coo).unwrap();
        assert!(m.numeric_symmetric);
        let x: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
        let (mut y1, mut y2) = (vec![0.0; 60], vec![0.0; 60]);
        m.spmv(&x, &mut y1);
        m.spmv_sym(&x, &mut y2);
        propcheck::assert_close(&y1, &y2, 1e-12, 1e-12).unwrap();
    }

    #[test]
    fn csr_roundtrip_preserves_matrix() {
        let coo = paper_fig1();
        let m = Csrc::from_coo(&coo).unwrap();
        let back = m.to_csr();
        let orig = Csr::from_coo(&coo);
        assert_eq!(back.ia, orig.ia);
        assert_eq!(back.ja, orig.ja);
        assert_eq!(back.a, orig.a);
    }

    #[test]
    fn ell_export_roundtrip() {
        let coo = paper_fig1();
        let m = Csrc::from_coo(&coo).unwrap();
        let w = m.max_row_width();
        let ell = m.to_ell(16, w).unwrap();
        assert_eq!(ell.n, 16);
        // Row widths over w fail cleanly.
        assert!(m.to_ell(16, 0).is_none());
        assert!(m.to_ell(4, w).is_none());
        // ELL spmv oracle agrees with csrc.
        let mut rng = Rng::new(4);
        let x: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        let mut xpad = vec![0.0f32; 16];
        for (d, s) in xpad.iter_mut().zip(&x) {
            *d = *s as f32;
        }
        let ypad = ell.spmv_ref(&xpad);
        let mut y = vec![0.0; 9];
        m.spmv_into_zeroed(&x, &mut y);
        for i in 0..9 {
            assert!((ypad[i] as f64 - y[i]).abs() < 1e-3, "row {i}");
        }
    }

    #[test]
    fn counters_match_paper_formulas() {
        let coo = paper_fig1();
        let m = Csrc::from_coo(&coo).unwrap();
        let nnz = m.nnz();
        assert_eq!(m.flops(), 2 * nnz - 9);
        assert_eq!(m.loads(), (5 * nnz - 9) / 2);
        // load:flop ratio ≈ 1.26 for large matrices (§4.1).
        let ratio = m.loads() as f64 / m.flops() as f64;
        assert!(ratio < 1.5 && ratio > 1.1, "ratio = {ratio}");
    }

    #[test]
    fn property_spmv_matches_csr_spmv() {
        propcheck::check(25, |rng| {
            let n = 8 + rng.below(60);
            let npr = 1 + rng.below(6);
            let sym = rng.below(2) == 0;
            let coo = Coo::random_structurally_symmetric(n, npr, sym, rng);
            let csr = Csr::from_coo(&coo);
            let m = Csrc::from_csr(&csr).map_err(|e| e.to_string())?;
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let (mut y1, mut y2) = (vec![0.0; n], vec![0.0; n]);
            csr.spmv(&x, &mut y1);
            m.spmv_into_zeroed(&x, &mut y2);
            propcheck::assert_close(&y1, &y2, 1e-11, 1e-11)
        });
    }

    #[test]
    fn property_panel_spmm_matches_k_serial_spmv() {
        // The fused k-wide sweep must equal k independent products for
        // every k, including k > 8 (the register-panel chunk width).
        propcheck::check(15, |rng| {
            let n = 8 + rng.below(50);
            let coo = Coo::random_structurally_symmetric(n, 4, false, rng);
            let m = Csrc::from_coo(&coo).map_err(|e| e.to_string())?;
            let k = 1 + rng.below(11);
            let xp: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();
            let mut yp = vec![0.0; n * k];
            m.spmv_panel(&xp, &mut yp, k);
            let (mut xc, mut yc) = (vec![0.0; n], vec![0.0; n]);
            for c in 0..k {
                for j in 0..n {
                    xc[j] = xp[j * k + c];
                }
                m.spmv_into_zeroed(&xc, &mut yc);
                for i in 0..n {
                    let got = yp[i * k + c];
                    if (got - yc[i]).abs() > 1e-11 * (1.0 + yc[i].abs()) {
                        return Err(format!("k={k} col {c} row {i}: {got} vs {}", yc[i]));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_half_bandwidth() {
        propcheck::check(10, |rng| {
            let hbw = 1 + rng.below(5);
            let coo = Coo::banded(40, hbw, false, rng);
            let m = Csrc::from_coo(&coo).map_err(|e| e.to_string())?;
            if m.half_bandwidth() != hbw {
                return Err(format!("expected hbw {hbw}, got {}", m.half_bandwidth()));
            }
            Ok(())
        });
    }

    #[test]
    fn pattern_fingerprint_ignores_values() {
        let mut rng = Rng::new(77);
        let a = Csrc::from_coo(&Coo::banded(60, 2, false, &mut rng)).unwrap();
        let b = Csrc::from_coo(&Coo::banded(60, 2, false, &mut rng)).unwrap();
        assert_ne!(a.al, b.al, "seeds must differ in values");
        assert_eq!(a.pattern_fingerprint(), b.pattern_fingerprint());
        let c = Csrc::from_coo(&Coo::banded(60, 3, false, &mut rng)).unwrap();
        assert_ne!(a.pattern_fingerprint(), c.pattern_fingerprint());
    }

    #[test]
    fn update_values_swaps_values_and_resymmetrizes() {
        let mut rng = Rng::new(78);
        let mut a = Csrc::from_coo(&Coo::banded(50, 2, false, &mut rng)).unwrap();
        assert!(!a.numeric_symmetric);
        let b = Csrc::from_coo(&Coo::banded(50, 2, true, &mut rng)).unwrap();
        a.update_values_from(&b).unwrap();
        assert_eq!(a.ad, b.ad);
        assert_eq!(a.al, b.al);
        assert_eq!(a.au, b.au);
        assert!(a.numeric_symmetric, "symmetric values must re-arm the §2.2 path");
        // Products now match the donor matrix exactly.
        let x: Vec<f64> = (0..50).map(|i| (i as f64).cos()).collect();
        let (mut ya, mut yb) = (vec![0.0; 50], vec![0.0; 50]);
        a.spmv_into_zeroed(&x, &mut ya);
        b.spmv_into_zeroed(&x, &mut yb);
        assert_eq!(ya, yb);
    }

    #[test]
    fn update_values_rejects_bad_shapes_without_panicking() {
        let mut rng = Rng::new(79);
        let mut a = Csrc::from_coo(&Coo::banded(30, 2, false, &mut rng)).unwrap();
        let before = a.al.clone();
        let err = a.update_values(&vec![0.0; 29], &vec![0.0; a.k()], &vec![0.0; a.k()]);
        assert!(matches!(err, Err(CsrcError::ValueLengthMismatch { want_n: 30, .. })));
        let err = a.update_values(&vec![0.0; 30], &vec![0.0; a.k() + 1], &vec![0.0; a.k()]);
        assert!(matches!(err, Err(CsrcError::ValueLengthMismatch { .. })));
        assert_eq!(a.al, before, "failed update must leave the matrix untouched");

        let other = Csrc::from_coo(&Coo::banded(30, 3, false, &mut rng)).unwrap();
        let err = a.update_values_from(&other);
        assert!(matches!(err, Err(CsrcError::PatternMismatch { .. })));
        assert_eq!(a.al, before);
    }
}
