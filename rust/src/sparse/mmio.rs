//! Matrix-Market I/O (coordinate format) — so the real University of
//! Florida files from Table 1 drop straight into the harness when
//! available, and so generated suites can be persisted and reloaded.
//!
//! Supports `matrix coordinate real|integer|pattern general|symmetric`.
//! Symmetric files store the lower triangle; reading expands mirrors.

use super::Coo;
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

#[derive(Debug)]
pub enum MmioError {
    Io(std::io::Error),
    Parse(String),
}

impl std::fmt::Display for MmioError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MmioError::Io(e) => write!(f, "mmio io error: {e}"),
            MmioError::Parse(m) => write!(f, "mmio parse error: {m}"),
        }
    }
}

impl std::error::Error for MmioError {}

impl From<std::io::Error> for MmioError {
    fn from(e: std::io::Error) -> Self {
        MmioError::Io(e)
    }
}

fn perr(msg: impl Into<String>) -> MmioError {
    MmioError::Parse(msg.into())
}

/// Read a Matrix-Market coordinate file into COO (1-based → 0-based).
pub fn read_matrix_market(path: &Path) -> Result<Coo, MmioError> {
    let f = std::fs::File::open(path)?;
    read_from(std::io::BufReader::new(f))
}

pub fn read_from<R: BufRead>(r: R) -> Result<Coo, MmioError> {
    let mut lines = r.lines();
    let header = lines.next().ok_or_else(|| perr("empty file"))??;
    let toks: Vec<&str> = header.split_whitespace().collect();
    if toks.len() < 5 || !toks[0].starts_with("%%MatrixMarket") {
        return Err(perr("missing %%MatrixMarket header"));
    }
    if toks[1] != "matrix" || toks[2] != "coordinate" {
        return Err(perr(format!("unsupported kind: {} {}", toks[1], toks[2])));
    }
    let field = toks[3]; // real | integer | pattern
    let sym = toks[4]; // general | symmetric | skew-symmetric
    if !matches!(field, "real" | "integer" | "pattern") {
        return Err(perr(format!("unsupported field: {field}")));
    }
    if !matches!(sym, "general" | "symmetric") {
        return Err(perr(format!("unsupported symmetry: {sym}")));
    }
    // Skip comments, read size line.
    let mut size_line = None;
    for line in lines.by_ref() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some(line);
        break;
    }
    let size_line = size_line.ok_or_else(|| perr("missing size line"))?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| t.parse().map_err(|_| perr(format!("bad size token {t}"))))
        .collect::<Result<_, _>>()?;
    if dims.len() != 3 {
        return Err(perr("size line needs: nrows ncols nnz"));
    }
    let (nrows, ncols, nnz) = (dims[0], dims[1], dims[2]);
    let mut coo = Coo::with_capacity(nrows, ncols, nnz * if sym == "symmetric" { 2 } else { 1 });
    // Duplicate coordinates are rejected, not summed: a coordinate file
    // listing (i,j) twice — or a symmetric file listing both (i,j) and
    // (j,i) — is malformed, and silently summing would corrupt values.
    let mut seen = std::collections::HashSet::with_capacity(nnz * 2);
    let mut count = 0usize;
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let i: usize = it
            .next()
            .ok_or_else(|| perr("short entry line"))?
            .parse()
            .map_err(|_| perr("bad row index"))?;
        let j: usize = it
            .next()
            .ok_or_else(|| perr("short entry line"))?
            .parse()
            .map_err(|_| perr("bad col index"))?;
        let v: f64 = if field == "pattern" {
            1.0
        } else {
            it.next().ok_or_else(|| perr("missing value"))?.parse().map_err(|_| perr("bad value"))?
        };
        if i == 0 || j == 0 || i > nrows || j > ncols {
            return Err(perr(format!("index ({i},{j}) out of 1..{nrows}x1..{ncols}")));
        }
        if !seen.insert((i, j)) {
            return Err(perr(format!("duplicate entry ({i},{j})")));
        }
        coo.push(i - 1, j - 1, v);
        if sym == "symmetric" && i != j {
            if !seen.insert((j, i)) {
                return Err(perr(format!("duplicate entry ({j},{i}) via symmetric mirror")));
            }
            coo.push(j - 1, i - 1, v);
        }
        count += 1;
    }
    if count != nnz {
        return Err(perr(format!("expected {nnz} entries, found {count}")));
    }
    coo.compact();
    Ok(coo)
}

/// Write COO as `matrix coordinate real general` (0-based → 1-based).
pub fn write_matrix_market(path: &Path, coo: &Coo, comment: &str) -> Result<(), MmioError> {
    write_matrix_market_with(path, coo, comment, false)
}

/// Write COO, optionally under a `symmetric` header storing only the
/// lower triangle (the matrix must then be numerically symmetric and
/// duplicate-free — verified before anything is written, so a failed
/// call produces a parse error rather than a half-written file).
pub fn write_matrix_market_with(
    path: &Path,
    coo: &Coo,
    comment: &str,
    symmetric: bool,
) -> Result<(), MmioError> {
    // Coordinate files cannot represent duplicate entries (the reader
    // rejects them), so an uncompacted assembly-style COO is summed the
    // same way the format converters sum it before anything is written.
    let compacted;
    let coo = if has_duplicate_coords(coo) {
        compacted = {
            let mut c = coo.clone();
            c.compact();
            c
        };
        &compacted
    } else {
        coo
    };
    if symmetric {
        if coo.nrows != coo.ncols {
            return Err(perr("symmetric output requires a square matrix"));
        }
        let mut map = std::collections::HashMap::with_capacity(coo.nnz());
        for ((&i, &j), &v) in coo.rows.iter().zip(&coo.cols).zip(&coo.vals) {
            map.insert((i, j), v);
        }
        for (&(i, j), &v) in &map {
            if i != j && map.get(&(j, i)) != Some(&v) {
                return Err(perr(format!(
                    "matrix is not numerically symmetric at ({}, {})",
                    i + 1,
                    j + 1
                )));
            }
        }
    }
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    let sym_tok = if symmetric { "symmetric" } else { "general" };
    writeln!(w, "%%MatrixMarket matrix coordinate real {sym_tok}")?;
    for line in comment.lines() {
        writeln!(w, "% {line}")?;
    }
    if symmetric {
        let kept: Vec<usize> = (0..coo.nnz()).filter(|&k| coo.rows[k] >= coo.cols[k]).collect();
        writeln!(w, "{} {} {}", coo.nrows, coo.ncols, kept.len())?;
        for k in kept {
            writeln!(w, "{} {} {:.17e}", coo.rows[k] + 1, coo.cols[k] + 1, coo.vals[k])?;
        }
    } else {
        writeln!(w, "{} {} {}", coo.nrows, coo.ncols, coo.nnz())?;
        for ((&i, &j), &v) in coo.rows.iter().zip(&coo.cols).zip(&coo.vals) {
            writeln!(w, "{} {} {:.17e}", i + 1, j + 1, v)?;
        }
    }
    Ok(())
}

fn has_duplicate_coords(coo: &Coo) -> bool {
    let mut seen = std::collections::HashSet::with_capacity(coo.nnz());
    coo.rows.iter().zip(&coo.cols).any(|(&i, &j)| !seen.insert((i, j)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_general() {
        let mut rng = Rng::new(20);
        let coo = Coo::random_structurally_symmetric(25, 3, false, &mut rng);
        let dir = std::env::temp_dir().join("csrc_mmio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.mtx");
        write_matrix_market(&path, &coo, "test matrix").unwrap();
        let back = read_matrix_market(&path).unwrap();
        assert_eq!(back.nrows, coo.nrows);
        assert_eq!(back.nnz(), coo.nnz());
        assert_eq!(back.rows, coo.rows);
        assert_eq!(back.cols, coo.cols);
        for (a, b) in back.vals.iter().zip(&coo.vals) {
            assert!((a - b).abs() < 1e-15);
        }
    }

    #[test]
    fn reads_symmetric_with_expansion() {
        let text = "%%MatrixMarket matrix coordinate real symmetric\n\
                    % a comment\n\
                    3 3 4\n\
                    1 1 2.0\n2 2 2.0\n3 3 2.0\n3 1 5.0\n";
        let coo = read_from(std::io::Cursor::new(text)).unwrap();
        assert_eq!(coo.nnz(), 5); // 3 diag + both mirrors of (3,1)
        assert!(coo.is_structurally_symmetric());
    }

    #[test]
    fn reads_pattern() {
        let text = "%%MatrixMarket matrix coordinate pattern general\n2 2 2\n1 1\n2 1\n";
        let coo = read_from(std::io::Cursor::new(text)).unwrap();
        assert_eq!(coo.vals, vec![1.0, 1.0]);
    }

    #[test]
    fn rejects_bad_header_and_counts() {
        assert!(read_from(std::io::Cursor::new("garbage\n")).is_err());
        let missing = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n";
        assert!(read_from(std::io::Cursor::new(missing)).is_err());
        let oob = "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n";
        assert!(read_from(std::io::Cursor::new(oob)).is_err());
    }

    #[test]
    fn rejects_duplicate_entries() {
        let dup = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n1 1 2.0\n";
        let err = read_from(std::io::Cursor::new(dup)).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
        // A symmetric file listing both mirrors of one pair is malformed.
        let both = "%%MatrixMarket matrix coordinate real symmetric\n2 2 2\n2 1 1.0\n1 2 1.0\n";
        let err = read_from(std::io::Cursor::new(both)).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn writer_compacts_assembly_duplicates_for_the_reader() {
        // FEM-style COO legitimately holds duplicate coordinates until
        // compact(); the writer must sum them so its own output stays
        // readable under the duplicate-rejecting reader.
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 0, 2.0);
        coo.push(1, 1, 3.0);
        let dir = std::env::temp_dir().join(format!("csrc_mmio_dup_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("dup.mtx");
        write_matrix_market(&path, &coo, "assembly duplicates").unwrap();
        let back = read_matrix_market(&path).unwrap();
        assert_eq!(back.nnz(), 2);
        let t = triplets(&back);
        assert_eq!(t[0], (0, 0, 3.0f64.to_bits()));
        assert_eq!(t[1], (1, 1, 3.0f64.to_bits()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn symmetric_writer_requires_numeric_symmetry() {
        let mut coo = Coo::new(2, 2);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 2.0); // mirrored pattern, mismatched values
        let dir = std::env::temp_dir().join(format!("csrc_mmio_sym_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.mtx");
        let err = write_matrix_market_with(&path, &coo, "t", true).unwrap_err();
        assert!(err.to_string().contains("not numerically symmetric"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Sorted structural triplets with bit-exact values — `{:.17e}`
    /// output round-trips f64 exactly, so equality is the right check.
    fn triplets(c: &Coo) -> Vec<(u32, u32, u64)> {
        let mut v: Vec<(u32, u32, u64)> = c
            .rows
            .iter()
            .zip(&c.cols)
            .zip(&c.vals)
            .map(|((&i, &j), &x)| (i, j, x.to_bits()))
            .collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn property_roundtrip_general_and_symmetric_headers() {
        use crate::util::propcheck;
        let dir = std::env::temp_dir().join(format!("csrc_mmio_prop_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        propcheck::check(8, |rng| {
            let n = 5 + rng.below(40);
            let npr = 1 + rng.below(4);
            // Numerically symmetric matrices exercise the `symmetric`
            // header (lower triangle only + mirror expansion on read);
            // others the `general` header.
            let sym = rng.below(2) == 0;
            let coo = Coo::random_structurally_symmetric(n, npr, sym, rng);
            let path = dir.join(format!("m_{}.mtx", rng.next_u64()));
            write_matrix_market_with(&path, &coo, "prop roundtrip", sym)
                .map_err(|e| e.to_string())?;
            let text = std::fs::read_to_string(&path).map_err(|e| e.to_string())?;
            let want_header = if sym { "symmetric" } else { "general" };
            if !text.lines().next().unwrap_or("").contains(want_header) {
                return Err(format!("header must say {want_header}"));
            }
            let back = read_matrix_market(&path).map_err(|e| e.to_string())?;
            if (back.nrows, back.ncols) != (coo.nrows, coo.ncols) {
                return Err("shape changed".into());
            }
            if triplets(&back) != triplets(&coo) {
                return Err(format!(
                    "triplets changed across {} roundtrip (nnz {} -> {})",
                    want_header,
                    coo.nnz(),
                    back.nnz()
                ));
            }
            Ok(())
        });
        let _ = std::fs::remove_dir_all(&dir);
    }
}
