//! COO (triplet) format — the assembly staging area.
//!
//! Every generator (`gen/`) and the Matrix-Market reader produce a [`Coo`];
//! conversions to CSR/CSRC sort, deduplicate (summing duplicates, the FEM
//! assembly convention) and compress.

use crate::util::Rng;

/// Coordinate-format sparse matrix; entries may be unsorted and may contain
/// duplicates until [`Coo::compact`] is called.
#[derive(Clone, Debug, Default)]
pub struct Coo {
    pub nrows: usize,
    pub ncols: usize,
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
}

impl Coo {
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Self { nrows, ncols, rows: Vec::new(), cols: Vec::new(), vals: Vec::new() }
    }

    pub fn with_capacity(nrows: usize, ncols: usize, cap: usize) -> Self {
        Self {
            nrows,
            ncols,
            rows: Vec::with_capacity(cap),
            cols: Vec::with_capacity(cap),
            vals: Vec::with_capacity(cap),
        }
    }

    #[inline]
    pub fn push(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.nrows && j < self.ncols, "({i},{j}) out of {}x{}", self.nrows, self.ncols);
        self.rows.push(i as u32);
        self.cols.push(j as u32);
        self.vals.push(v);
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Sort by (row, col) and sum duplicate coordinates (FEM assembly
    /// semantics). Zero-valued entries are *kept*: structural non-zeros
    /// with value 0 are legal and matter for symmetry of the pattern.
    pub fn compact(&mut self) {
        let n = self.nnz();
        let mut order: Vec<u32> = (0..n as u32).collect();
        let (rows, cols) = (&self.rows, &self.cols);
        order.sort_unstable_by_key(|&k| ((rows[k as usize] as u64) << 32) | cols[k as usize] as u64);
        let mut r = Vec::with_capacity(n);
        let mut c = Vec::with_capacity(n);
        let mut v = Vec::with_capacity(n);
        for &k in &order {
            let k = k as usize;
            if let (Some(&lr), Some(&lc)) = (r.last(), c.last()) {
                if lr == self.rows[k] && lc == self.cols[k] {
                    *v.last_mut().unwrap() += self.vals[k];
                    continue;
                }
            }
            r.push(self.rows[k]);
            c.push(self.cols[k]);
            v.push(self.vals[k]);
        }
        self.rows = r;
        self.cols = c;
        self.vals = v;
    }

    /// Is the *pattern* symmetric? (a_ij != structural-zero implies a_ji
    /// too; values are irrelevant.) Requires a compacted matrix.
    pub fn is_structurally_symmetric(&self) -> bool {
        if self.nrows != self.ncols {
            return false;
        }
        let mut pairs: Vec<u64> = self
            .rows
            .iter()
            .zip(&self.cols)
            .map(|(&i, &j)| ((i as u64) << 32) | j as u64)
            .collect();
        pairs.sort_unstable();
        self.rows.iter().zip(&self.cols).all(|(&i, &j)| {
            i == j || pairs.binary_search(&(((j as u64) << 32) | i as u64)).is_ok()
        })
    }

    /// Augment the pattern so it becomes structurally symmetric: for every
    /// (i, j) without a mirror, add an explicit zero at (j, i). Also ensures
    /// a full diagonal (CSRC stores ad(n) densely). Compacts first.
    pub fn symmetrize_pattern(&mut self) {
        assert_eq!(self.nrows, self.ncols, "pattern symmetrization needs a square matrix");
        self.compact();
        let mut pairs: Vec<u64> = self
            .rows
            .iter()
            .zip(&self.cols)
            .map(|(&i, &j)| ((i as u64) << 32) | j as u64)
            .collect();
        pairs.sort_unstable();
        let mut extra_r = Vec::new();
        let mut extra_c = Vec::new();
        for (&i, &j) in self.rows.iter().zip(&self.cols) {
            if i != j && pairs.binary_search(&(((j as u64) << 32) | i as u64)).is_err() {
                extra_r.push(j);
                extra_c.push(i);
            }
        }
        let mut have_diag = vec![false; self.nrows];
        for (&i, &j) in self.rows.iter().zip(&self.cols) {
            if i == j {
                have_diag[i as usize] = true;
            }
        }
        for (i, have) in have_diag.iter().enumerate() {
            if !have {
                extra_r.push(i as u32);
                extra_c.push(i as u32);
            }
        }
        self.rows.extend_from_slice(&extra_r);
        self.cols.extend_from_slice(&extra_c);
        self.vals.extend(std::iter::repeat(0.0).take(extra_r.len()));
        self.compact();
    }

    /// Dense oracle (tests only; O(n^2) memory).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut a = vec![vec![0.0; self.ncols]; self.nrows];
        for ((&i, &j), &v) in self.rows.iter().zip(&self.cols).zip(&self.vals) {
            a[i as usize][j as usize] += v;
        }
        a
    }

    /// Seeded random structurally-symmetric matrix with ~`nnz_per_row`
    /// off-diagonals per row, optionally numerically symmetric. Always has
    /// a full (dominant) diagonal so solver tests get SPD-ish systems.
    pub fn random_structurally_symmetric(
        n: usize,
        nnz_per_row: usize,
        numeric_symmetric: bool,
        rng: &mut Rng,
    ) -> Coo {
        let mut coo = Coo::with_capacity(n, n, n * (nnz_per_row + 1));
        for i in 0..n {
            coo.push(i, i, 4.0 + rng.normal().abs() + 2.0 * nnz_per_row as f64);
        }
        for i in 1..n {
            let k = nnz_per_row.min(i).min(1 + rng.below(nnz_per_row.max(1)));
            for j in rng.distinct_below(k, i) {
                let v = rng.normal();
                coo.push(i, j, v);
                coo.push(j, i, if numeric_symmetric { v } else { rng.normal() });
            }
        }
        coo.compact();
        coo
    }

    /// Banded structurally-symmetric matrix: half-bandwidth `hbw`, full
    /// band. The torsion1/minsurfo/dixmaanl analogues (smallest bandwidth
    /// in Table 1) use hbw 1–2.
    pub fn banded(n: usize, hbw: usize, numeric_symmetric: bool, rng: &mut Rng) -> Coo {
        let mut coo = Coo::with_capacity(n, n, n * (2 * hbw + 1));
        for i in 0..n {
            coo.push(i, i, 4.0 + 2.0 * hbw as f64 + rng.normal().abs());
            for j in i.saturating_sub(hbw)..i {
                let v = rng.normal();
                coo.push(i, j, v);
                coo.push(j, i, if numeric_symmetric { v } else { rng.normal() });
            }
        }
        coo.compact();
        coo
    }

    /// Fully dense matrix (the paper's `dense_1000`).
    pub fn dense_random(n: usize, rng: &mut Rng) -> Coo {
        let mut coo = Coo::with_capacity(n, n, n * n);
        for i in 0..n {
            for j in 0..n {
                let v = if i == j { n as f64 + rng.normal().abs() } else { rng.normal() };
                coo.push(i, j, v);
            }
        }
        coo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_sums_duplicates() {
        let mut c = Coo::new(3, 3);
        c.push(1, 2, 1.0);
        c.push(1, 2, 2.5);
        c.push(0, 0, 1.0);
        c.compact();
        assert_eq!(c.nnz(), 2);
        assert_eq!(c.rows, vec![0, 1]);
        assert_eq!(c.cols, vec![0, 2]);
        assert_eq!(c.vals, vec![1.0, 3.5]);
    }

    #[test]
    fn structural_symmetry_detection() {
        let mut c = Coo::new(3, 3);
        c.push(0, 0, 1.0);
        c.push(1, 1, 1.0);
        c.push(2, 2, 1.0);
        c.push(0, 2, 5.0);
        c.compact();
        assert!(!c.is_structurally_symmetric());
        c.push(2, 0, 0.0); // explicit zero still counts structurally
        c.compact();
        assert!(c.is_structurally_symmetric());
    }

    #[test]
    fn symmetrize_adds_mirrors_and_diagonal() {
        let mut c = Coo::new(4, 4);
        c.push(3, 0, 2.0);
        c.push(1, 2, 1.0);
        c.symmetrize_pattern();
        assert!(c.is_structurally_symmetric());
        // All 4 diagonal entries present.
        let diag = c.rows.iter().zip(&c.cols).filter(|(i, j)| i == j).count();
        assert_eq!(diag, 4);
        // Mirror (0,3) exists with value 0.
        let idx = c.rows.iter().zip(&c.cols).position(|(&i, &j)| i == 0 && j == 3).unwrap();
        assert_eq!(c.vals[idx], 0.0);
    }

    #[test]
    fn random_structurally_symmetric_is() {
        let mut rng = Rng::new(1);
        let c = Coo::random_structurally_symmetric(50, 4, false, &mut rng);
        assert!(c.is_structurally_symmetric());
        assert_eq!(c.nrows, 50);
    }

    #[test]
    fn banded_has_expected_band() {
        let mut rng = Rng::new(2);
        let c = Coo::banded(20, 2, true, &mut rng);
        assert!(c.is_structurally_symmetric());
        for (&i, &j) in c.rows.iter().zip(&c.cols) {
            assert!((i as i64 - j as i64).abs() <= 2);
        }
    }

    #[test]
    fn rectangular_not_symmetric() {
        let mut c = Coo::new(2, 3);
        c.push(0, 0, 1.0);
        c.compact();
        assert!(!c.is_structurally_symmetric());
    }
}
