//! Rectangular CSRC (§2.1 of the paper).
//!
//! In overlapping domain decomposition an n×m local matrix (m > n) splits
//! as A = A_S + A_R where A_S (n×n) has a structurally symmetric pattern
//! and A_R (n×k, k = m−n) holds the couplings to the overlap nodes. A_S is
//! stored in CSRC; A_R in an auxiliary CSR whose column indices live in
//! [n, m). The SpMV is Fig. 2(b): the CSRC sweep plus a second inner loop
//! over the rectangular part.

use super::{Coo, Csr, Csrc, CsrcError};

#[derive(Clone, Debug)]
pub struct CsrcRect {
    /// Square part (n×n), structurally symmetric.
    pub square: Csrc,
    /// Rectangular part as CSR over columns [n, m).
    pub iar: Vec<u32>,
    pub jar: Vec<u32>,
    pub ar: Vec<f64>,
    /// Total column count m ≥ n.
    pub m: usize,
}

impl CsrcRect {
    /// Split an n×m COO (m ≥ n) into CSRC square part + CSR rectangle.
    /// Fails if the square part's pattern is not structurally symmetric.
    pub fn from_coo(coo: &Coo) -> Result<CsrcRect, CsrcError> {
        let (n, m) = (coo.nrows, coo.ncols);
        assert!(m >= n, "CsrcRect expects m >= n, got {n}x{m}");
        let mut sq = Coo::with_capacity(n, n, coo.nnz());
        let mut rect = Coo::with_capacity(n, m - n, coo.nnz() / 4 + 1);
        for ((&i, &j), &v) in coo.rows.iter().zip(&coo.cols).zip(&coo.vals) {
            if (j as usize) < n {
                sq.push(i as usize, j as usize, v);
            } else {
                rect.push(i as usize, j as usize - n, v);
            }
        }
        sq.compact();
        rect.compact();
        let square = Csrc::from_coo(&sq)?;
        let rcsr = Csr::from_coo(&rect);
        Ok(CsrcRect { square, iar: rcsr.ia, jar: rcsr.ja, ar: rcsr.a, m })
    }

    pub fn n(&self) -> usize {
        self.square.n
    }

    pub fn nnz(&self) -> usize {
        self.square.nnz() + self.ar.len()
    }

    /// Fig. 2(b): y (len n) = A x (len m).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        let n = self.n();
        debug_assert_eq!(x.len(), self.m);
        debug_assert_eq!(y.len(), n);
        y.fill(0.0);
        for i in 0..n {
            let xi = x[i];
            let mut t = self.square.ad[i] * xi;
            for k in self.square.row_range(i) {
                let j = self.square.ja[k] as usize;
                t += self.square.al[k] * x[j];
                y[j] += self.square.au[k] * xi;
            }
            for k in self.iar[i] as usize..self.iar[i + 1] as usize {
                t += self.ar[k] * x[n + self.jar[k] as usize];
            }
            y[i] += t;
        }
    }

    /// Panel form of Fig. 2(b): Y (n×k, row-major) = A X (m×k, row-major).
    /// Each nonzero is read once per panel, mirroring `Csrc::spmv_multi`,
    /// so sharded serving composes with the batcher's coalesced SpMM
    /// instead of de-interleaving into k scalar products.
    pub fn spmv_multi(&self, x: &[f64], y: &mut [f64], k: usize) {
        if k == 1 {
            self.spmv(x, y);
            return;
        }
        let n = self.n();
        debug_assert_eq!(x.len(), self.m * k);
        debug_assert_eq!(y.len(), n * k);
        y.fill(0.0);
        let mut t = vec![0.0; k];
        for i in 0..n {
            let xi = &x[i * k..i * k + k];
            let adi = self.square.ad[i];
            for c in 0..k {
                t[c] = adi * xi[c];
            }
            for kk in self.square.row_range(i) {
                let j = self.square.ja[kk] as usize;
                let (al, au) = (self.square.al[kk], self.square.au[kk]);
                let xj = &x[j * k..j * k + k];
                let yj = &mut y[j * k..j * k + k];
                for c in 0..k {
                    t[c] += al * xj[c];
                    yj[c] += au * xi[c];
                }
            }
            for kk in self.iar[i] as usize..self.iar[i + 1] as usize {
                let ar = self.ar[kk];
                let j = n + self.jar[kk] as usize;
                let xj = &x[j * k..j * k + k];
                for c in 0..k {
                    t[c] += ar * xj[c];
                }
            }
            let yi = &mut y[i * k..i * k + k];
            for c in 0..k {
                yi[c] += t[c];
            }
        }
    }

    /// Coupling-only sweep: y (len n) += A_R · halo (len m−n). The halo
    /// vector is indexed by *local ghost column* (0-based), i.e. the
    /// caller has already gathered x[ghosts[..]] — this is the front
    /// router's gather-side contribution in sharded serving.
    pub fn coupling_spmv_into(&self, halo: &[f64], y: &mut [f64]) {
        let n = self.n();
        debug_assert_eq!(halo.len(), self.m - n);
        debug_assert_eq!(y.len(), n);
        for i in 0..n {
            let mut t = 0.0;
            for k in self.iar[i] as usize..self.iar[i + 1] as usize {
                t += self.ar[k] * halo[self.jar[k] as usize];
            }
            y[i] += t;
        }
    }

    /// Panel form of the coupling sweep: Y (n×k) += A_R · HALO ((m−n)×k),
    /// both row-major.
    pub fn coupling_spmv_multi_into(&self, halo: &[f64], y: &mut [f64], k: usize) {
        let n = self.n();
        debug_assert_eq!(halo.len(), (self.m - n) * k);
        debug_assert_eq!(y.len(), n * k);
        for i in 0..n {
            let yi = &mut y[i * k..i * k + k];
            for kk in self.iar[i] as usize..self.iar[i + 1] as usize {
                let ar = self.ar[kk];
                let hj = &halo[self.jar[kk] as usize * k..][..k];
                for c in 0..k {
                    yi[c] += ar * hj[c];
                }
            }
        }
    }

    pub fn working_set_bytes(&self) -> usize {
        self.square.working_set_bytes()
            + (self.iar.len() + self.jar.len()) * 4
            + self.ar.len() * 8
            + (self.m - self.n()) * 8 // the extra tail of x
    }

    pub fn flops(&self) -> usize {
        self.square.flops() + 2 * self.ar.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{propcheck, Rng};

    fn random_rect(n: usize, m: usize, rng: &mut Rng) -> Coo {
        let mut coo = Coo::new(n, m);
        // Structurally symmetric square part.
        let sq = Coo::random_structurally_symmetric(n, 3, false, rng);
        for ((&i, &j), &v) in sq.rows.iter().zip(&sq.cols).zip(&sq.vals) {
            coo.push(i as usize, j as usize, v);
        }
        // Rectangular couplings (only when there is an overlap region).
        if m > n {
            for i in 0..n {
                for _ in 0..rng.below(3) {
                    coo.push(i, n + rng.below(m - n), rng.normal());
                }
            }
        }
        coo.compact();
        coo
    }

    #[test]
    fn spmv_matches_dense() {
        let mut rng = Rng::new(8);
        let coo = random_rect(20, 28, &mut rng);
        let rect = CsrcRect::from_coo(&coo).unwrap();
        assert_eq!(rect.n(), 20);
        assert_eq!(rect.m, 28);
        let dense = coo.to_dense();
        let x: Vec<f64> = (0..28).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; 20];
        rect.spmv(&x, &mut y);
        for i in 0..20 {
            let want: f64 = (0..28).map(|j| dense[i][j] * x[j]).sum();
            assert!((y[i] - want).abs() < 1e-10, "row {i}");
        }
    }

    #[test]
    fn square_only_matrix_works() {
        let mut rng = Rng::new(9);
        let sq = Coo::random_structurally_symmetric(15, 3, true, &mut rng);
        let rect = CsrcRect::from_coo(&sq).unwrap();
        assert_eq!(rect.m, 15);
        assert_eq!(rect.ar.len(), 0);
        let x: Vec<f64> = (0..15).map(|_| rng.normal()).collect();
        let (mut y1, mut y2) = (vec![0.0; 15], vec![0.0; 15]);
        rect.spmv(&x, &mut y1);
        rect.square.spmv_into_zeroed(&x, &mut y2);
        propcheck::assert_close(&y1, &y2, 1e-12, 1e-12).unwrap();
    }

    #[test]
    fn rejects_asymmetric_square_part() {
        let mut coo = Coo::new(3, 5);
        for i in 0..3 {
            coo.push(i, i, 1.0);
        }
        coo.push(2, 0, 1.0); // unmirrored inside square part
        coo.push(0, 4, 1.0); // rectangular part — fine
        coo.compact();
        assert!(CsrcRect::from_coo(&coo).is_err());
    }

    #[test]
    fn spmv_multi_matches_column_by_column() {
        let mut rng = Rng::new(21);
        let coo = random_rect(24, 33, &mut rng);
        let rect = CsrcRect::from_coo(&coo).unwrap();
        let (n, m) = (rect.n(), rect.m);
        for k in [1, 2, 4, 7] {
            let x: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
            let mut y = vec![0.0; n * k];
            rect.spmv_multi(&x, &mut y, k);
            for c in 0..k {
                let xc: Vec<f64> = (0..m).map(|j| x[j * k + c]).collect();
                let mut yc = vec![0.0; n];
                rect.spmv(&xc, &mut yc);
                let got: Vec<f64> = (0..n).map(|i| y[i * k + c]).collect();
                propcheck::assert_close(&got, &yc, 1e-12, 1e-12)
                    .unwrap_or_else(|e| panic!("k={k} col {c}: {e}"));
            }
        }
    }

    #[test]
    fn coupling_sweep_matches_full_minus_square() {
        let mut rng = Rng::new(22);
        let coo = random_rect(18, 26, &mut rng);
        let rect = CsrcRect::from_coo(&coo).unwrap();
        let (n, m) = (rect.n(), rect.m);
        let x: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        // Full rectangular product...
        let mut yfull = vec![0.0; n];
        rect.spmv(&x, &mut yfull);
        // ...equals square part + coupling-only sweep over the halo tail.
        let mut ysplit = vec![0.0; n];
        rect.square.spmv_into_zeroed(&x[..n], &mut ysplit);
        rect.coupling_spmv_into(&x[n..], &mut ysplit);
        propcheck::assert_close(&yfull, &ysplit, 1e-12, 1e-12).unwrap();

        // Panel variant against k scalar coupling sweeps.
        let k = 3;
        let halo: Vec<f64> = (0..(m - n) * k).map(|_| rng.normal()).collect();
        let mut yp = vec![0.0; n * k];
        rect.coupling_spmv_multi_into(&halo, &mut yp, k);
        for c in 0..k {
            let hc: Vec<f64> = (0..m - n).map(|j| halo[j * k + c]).collect();
            let mut yc = vec![0.0; n];
            rect.coupling_spmv_into(&hc, &mut yc);
            let got: Vec<f64> = (0..n).map(|i| yp[i * k + c]).collect();
            propcheck::assert_close(&got, &yc, 1e-13, 1e-13).unwrap();
        }
    }

    #[test]
    fn property_rect_spmv_vs_dense() {
        propcheck::check(15, |rng| {
            let n = 5 + rng.below(20);
            let m = n + rng.below(10);
            let coo = random_rect(n, m, rng);
            let rect = CsrcRect::from_coo(&coo).map_err(|e| e.to_string())?;
            let dense = coo.to_dense();
            let x: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let mut y = vec![0.0; n];
            rect.spmv(&x, &mut y);
            let want: Vec<f64> = (0..n)
                .map(|i| (0..m).map(|j| dense[i][j] * x[j]).sum())
                .collect();
            propcheck::assert_close(&y, &want, 1e-10, 1e-10)
        });
    }
}
