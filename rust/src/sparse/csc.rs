//! CSC — compressed sparse column, the transpose view of CSR.
//!
//! Needed as the §5 contrast: computing Aᵀx with CSR means either an
//! expensive scatter sweep or converting to CSC first; CSRC gets the
//! transpose by swapping two pointers.

use super::{Coo, Csr, LinOp};

#[derive(Clone, Debug)]
pub struct Csc {
    pub nrows: usize,
    pub ncols: usize,
    /// Column pointers (len ncols+1).
    pub ja: Vec<u32>,
    /// Row indices (len nnz).
    pub ia: Vec<u32>,
    pub a: Vec<f64>,
}

impl Csc {
    pub fn from_csr(csr: &Csr) -> Csc {
        let nnz = csr.nnz();
        let mut colptr = vec![0u32; csr.ncols + 1];
        for &j in &csr.ja {
            colptr[j as usize + 1] += 1;
        }
        for j in 0..csr.ncols {
            colptr[j + 1] += colptr[j];
        }
        let mut next = colptr.clone();
        let mut ia = vec![0u32; nnz];
        let mut a = vec![0.0; nnz];
        for i in 0..csr.nrows {
            for k in csr.row_range(i) {
                let j = csr.ja[k] as usize;
                let dst = next[j] as usize;
                ia[dst] = i as u32;
                a[dst] = csr.a[k];
                next[j] += 1;
            }
        }
        Csc { nrows: csr.nrows, ncols: csr.ncols, ja: colptr, ia, a }
    }

    pub fn from_coo(coo: &Coo) -> Csc {
        Csc::from_csr(&Csr::from_coo(coo))
    }

    pub fn nnz(&self) -> usize {
        self.a.len()
    }

    /// y = A x via column sweep (scatter).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.ncols);
        debug_assert_eq!(y.len(), self.nrows);
        y.fill(0.0);
        for j in 0..self.ncols {
            let xj = x[j];
            for k in self.ja[j] as usize..self.ja[j + 1] as usize {
                y[self.ia[k] as usize] += self.a[k] * xj;
            }
        }
    }

    /// y = Aᵀ x: for CSC this is the gather sweep (cheap).
    pub fn spmv_t(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.nrows);
        debug_assert_eq!(y.len(), self.ncols);
        for j in 0..self.ncols {
            let mut t = 0.0;
            for k in self.ja[j] as usize..self.ja[j + 1] as usize {
                t += self.a[k] * x[self.ia[k] as usize];
            }
            y[j] = t;
        }
    }
}

impl LinOp for Csc {
    fn dim(&self) -> usize {
        assert_eq!(self.nrows, self.ncols);
        self.nrows
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.spmv(x, y)
    }
    fn apply_t(&self, x: &[f64], y: &mut [f64]) -> Result<(), String> {
        self.spmv_t(x, y);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn csc_spmv_matches_csr() {
        let mut rng = Rng::new(5);
        let coo = Coo::random_structurally_symmetric(30, 4, false, &mut rng);
        let csr = Csr::from_coo(&coo);
        let csc = Csc::from_csr(&csr);
        assert_eq!(csc.nnz(), csr.nnz());
        let x: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let (mut y1, mut y2) = (vec![0.0; 30], vec![0.0; 30]);
        csr.spmv(&x, &mut y1);
        csc.spmv(&x, &mut y2);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn csc_transpose_matches_csr_transpose() {
        let mut rng = Rng::new(6);
        let coo = Coo::random_structurally_symmetric(25, 3, false, &mut rng);
        let csr = Csr::from_coo(&coo);
        let csc = Csc::from_csr(&csr);
        let x: Vec<f64> = (0..25).map(|_| rng.normal()).collect();
        let (mut y1, mut y2) = (vec![0.0; 25], vec![0.0; 25]);
        csr.spmv_t(&x, &mut y1);
        csc.spmv_t(&x, &mut y2);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn rectangular_shapes() {
        let mut coo = Coo::new(2, 4);
        coo.push(0, 3, 2.0);
        coo.push(1, 0, 1.0);
        coo.compact();
        let csc = Csc::from_coo(&coo);
        let mut y = vec![0.0; 2];
        csc.spmv(&[1.0, 0.0, 0.0, 10.0], &mut y);
        assert_eq!(y, vec![20.0, 1.0]);
    }
}
