//! Autotuner — measurement-driven per-matrix engine selection.
//!
//! The paper's headline result is that **no single strategy wins
//! everywhere**: local buffers beat coloring for most matrices, but the
//! best accumulation method varies with structure (§4). This subsystem
//! makes that observation operational instead of leaving the choice to
//! the caller:
//!
//! 1. [`Features::extract`] reads the structural signals the decision
//!    depends on (order, work, scatter ratio, write bandwidth, color and
//!    interval counts, partition balance) from a [`SpmvKernel`] and its
//!    full [`SpmvPlan`];
//! 2. [`tune`] runs short measured trials of every candidate engine —
//!    the paper's median-of-runs protocol
//!    ([`crate::metrics::median_and_spread_of_runs`]) under a
//!    configurable [`TrialBudget`] — and emits a [`Decision`];
//! 3. a zero budget skips the trials and falls back to [`cost_model`],
//!    a paper-derived heuristic over the same features;
//! 4. [`resolve`] fronts the whole thing with a persistent
//!    [`DecisionCache`] keyed by (structure [`fingerprint`] ×
//!    thread-count), so a restarted service never re-tunes a known
//!    matrix.
//!
//! [`crate::parallel::EngineKind::Auto`] is the routing-level entry
//! point: the coordinator resolves it here at registration time and the
//! workers only ever see concrete engines.

pub mod cache;
pub mod features;

pub use cache::DecisionCache;
pub use features::{fingerprint, Features};

use crate::metrics;
use crate::parallel::{build_engine, AccumMethod, EngineKind};
use crate::plan::{PlanPieces, SpmvPlan};
use crate::sparse::SpmvKernel;
use std::sync::Arc;
use std::time::Instant;

/// How much measuring a tuning run may do: `runs` timed repetitions of
/// `products` back-to-back products per candidate engine (the paper's §4
/// protocol, scaled down). A zero budget means "no trials": the decision
/// comes from [`cost_model`] alone.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TrialBudget {
    pub runs: usize,
    pub products: usize,
}

impl Default for TrialBudget {
    fn default() -> Self {
        TrialBudget { runs: 3, products: 8 }
    }
}

impl TrialBudget {
    /// No measuring at all — [`tune`] answers from the cost model.
    pub fn zero() -> TrialBudget {
        TrialBudget { runs: 0, products: 0 }
    }

    /// Cheapest measured budget (CI smoke runs).
    pub fn smoke() -> TrialBudget {
        TrialBudget { runs: 1, products: 2 }
    }

    pub fn is_zero(&self) -> bool {
        self.runs == 0 || self.products == 0
    }
}

/// One candidate's measurement.
#[derive(Clone, Debug)]
pub struct TrialResult {
    pub kind: EngineKind,
    /// Median seconds per product across the budgeted runs.
    pub seconds_per_product: f64,
    /// MAD across runs — how noisy the median is.
    pub mad_s: f64,
    /// Rate normalized by the kernel's work units ([`Features::work_flops`]).
    pub mflops: f64,
}

/// The tuner's verdict for one matrix × thread-count.
#[derive(Clone, Debug)]
pub struct Decision {
    /// The winning concrete engine (never [`EngineKind::Auto`]).
    pub kind: EngineKind,
    /// The winner's measured rate (0 when `measured` is false).
    pub mflops: f64,
    /// False when the decision came from [`cost_model`] without trials.
    pub measured: bool,
    /// Wall-clock seconds the tuning run itself cost.
    pub tuned_s: f64,
    /// Structure fingerprint — the cache key, with `nthreads`.
    pub fingerprint: u64,
    pub nthreads: usize,
    pub features: Features,
    pub trials: Vec<TrialResult>,
}

/// The candidate set for a thread count: every concrete engine that can
/// possibly win, including the sequential baseline (small matrices do not
/// amortize fork-join — the paper's §4.2 one-thread shortcut) and the
/// atomics baseline the paper dismisses (measurement, not folklore,
/// decides).
pub fn candidates(nthreads: usize) -> Vec<EngineKind> {
    let mut v = vec![EngineKind::Sequential];
    if nthreads > 1 {
        v.extend(EngineKind::all_local_buffers());
        v.push(EngineKind::Colorful);
        v.push(EngineKind::Atomic);
    }
    v
}

/// Plan pieces the tuner needs at a thread count — the union over
/// [`candidates`]. Everything at p ≥ 2; only the base partition at
/// p = 1, where the sole candidate is the sequential sweep and paying
/// for conflict coloring would be pure waste.
pub fn required_pieces(nthreads: usize) -> PlanPieces {
    let mut need = PlanPieces::default();
    for kind in candidates(nthreads) {
        need = need.union(PlanPieces::for_kind(kind));
    }
    need
}

/// Paper-derived heuristic over structural features — the zero-budget
/// fallback, also used by workers racing a registration-time tuning run.
///
/// * Small orders don't amortize fork-join: sequential (§4.2).
/// * A scatter-free kernel (CSR-like) has block-exact effective ranges,
///   so `local-buffers/effective` degenerates to the ideal row split.
/// * Almost-conflict-free patterns (≤ 2 colors) suit the colorful
///   schedule: barely any serialization between classes (§3.2).
/// * Otherwise local buffers win "for most matrices" (§4.3); a fine
///   interval decomposition indicates scattered write ranges where the
///   interval accumulation amortizes best, else effective accumulation.
pub fn cost_model(f: &Features) -> EngineKind {
    if f.nthreads <= 1 || f.n < 2048 {
        return EngineKind::Sequential;
    }
    if f.scatter_ratio == 0.0 {
        return EngineKind::LocalBuffers(AccumMethod::Effective);
    }
    if f.colors <= 2 {
        return EngineKind::Colorful;
    }
    if f.intervals > 4 * f.nthreads.max(1) {
        EngineKind::LocalBuffers(AccumMethod::Interval)
    } else {
        EngineKind::LocalBuffers(AccumMethod::Effective)
    }
}

/// Run the measured trials and pick a winner. `plan` must carry the
/// pieces every candidate at its thread count borrows
/// ([`required_pieces`]; `PlanBuilder::all` always suffices); panics
/// otherwise (programming error, same contract as [`build_engine`]).
pub fn tune(kernel: &Arc<dyn SpmvKernel>, plan: &Arc<SpmvPlan>, budget: &TrialBudget) -> Decision {
    tune_with_fingerprint(kernel, plan, budget, fingerprint(kernel.as_ref()))
}

/// [`tune`] with a caller-supplied fingerprint, so [`resolve`] — which
/// already hashed the structure for its cache lookup — does not pay the
/// O(nnz) pass twice on a miss.
fn tune_with_fingerprint(
    kernel: &Arc<dyn SpmvKernel>,
    plan: &Arc<SpmvPlan>,
    budget: &TrialBudget,
    fp: u64,
) -> Decision {
    assert!(
        plan.pieces.covers(required_pieces(plan.nthreads)),
        "the tuner trials every candidate engine: build the plan with \
         PlanBuilder::all or tuner::required_pieces"
    );
    let t0 = Instant::now();
    let features = Features::extract(kernel.as_ref(), plan);
    if budget.is_zero() {
        let kind = cost_model(&features);
        return Decision {
            kind,
            mflops: 0.0,
            measured: false,
            tuned_s: t0.elapsed().as_secs_f64(),
            fingerprint: fp,
            nthreads: plan.nthreads,
            features,
            trials: Vec::new(),
        };
    }
    let n = kernel.dim();
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.001).sin()).collect();
    let mut y = vec![0.0; n];
    let work = features.work_flops;
    let mut trials = Vec::new();
    for kind in candidates(plan.nthreads) {
        let mut engine = build_engine(kind, kernel.clone(), plan.clone());
        let (per, mad) = metrics::median_and_spread_of_runs(budget.runs, budget.products, || {
            engine.spmv(&x, &mut y)
        });
        trials.push(TrialResult {
            kind,
            seconds_per_product: per,
            mad_s: mad,
            mflops: metrics::mflops(work, per),
        });
    }
    let best = trials
        .iter()
        .max_by(|a, b| a.mflops.partial_cmp(&b.mflops).expect("rates are finite"))
        .expect("candidates is never empty");
    Decision {
        kind: best.kind,
        mflops: best.mflops,
        measured: true,
        tuned_s: t0.elapsed().as_secs_f64(),
        fingerprint: fp,
        nthreads: plan.nthreads,
        features,
        trials,
    }
}

/// Cache-fronted [`tune`]: returns the decision plus whether it came
/// from the cache (`true` = zero new trials were run).
///
/// A cached *unmeasured* (cost-model) decision does not satisfy a caller
/// that brought a measuring budget: it is re-tuned and the cache entry
/// upgraded — otherwise one zero-budget call would freeze the heuristic
/// pick forever.
pub fn resolve(
    kernel: &Arc<dyn SpmvKernel>,
    plan: &Arc<SpmvPlan>,
    budget: &TrialBudget,
    cache: &DecisionCache,
) -> (Decision, bool) {
    let fp = fingerprint(kernel.as_ref());
    if let Some(d) = cache.peek(fp, plan.nthreads) {
        if d.measured || budget.is_zero() {
            cache.record(true);
            return (d, true);
        }
    }
    cache.record(false);
    let d = tune_with_fingerprint(kernel, plan, budget, fp);
    cache.put(d.clone());
    (d, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::PlanBuilder;
    use crate::sparse::{Coo, Csr, Csrc};
    use crate::util::Rng;

    fn kernel_and_plan(n: usize, seed: u64, p: usize) -> (Arc<dyn SpmvKernel>, Arc<SpmvPlan>) {
        let mut rng = Rng::new(seed);
        let coo = Coo::random_structurally_symmetric(n, 4, false, &mut rng);
        let kernel: Arc<dyn SpmvKernel> = Arc::new(Csrc::from_coo(&coo).unwrap());
        let plan = Arc::new(PlanBuilder::all(p).build(kernel.as_ref()));
        (kernel, plan)
    }

    #[test]
    fn tune_picks_a_measured_concrete_winner() {
        let (kernel, plan) = kernel_and_plan(150, 1, 2);
        let d = tune(&kernel, &plan, &TrialBudget::smoke());
        assert!(d.measured);
        assert_ne!(d.kind, EngineKind::Auto);
        assert_eq!(d.trials.len(), candidates(2).len());
        assert!(d.mflops > 0.0);
        assert!(d.tuned_s > 0.0);
        // The winner really is the argmax of the trials.
        let best = d.trials.iter().map(|t| t.mflops).fold(0.0, f64::max);
        assert_eq!(d.mflops, best);
        assert_eq!(d.nthreads, 2);
        assert_eq!(d.fingerprint, fingerprint(kernel.as_ref()));
    }

    #[test]
    fn zero_budget_answers_from_cost_model() {
        let (kernel, plan) = kernel_and_plan(100, 2, 3);
        let d = tune(&kernel, &plan, &TrialBudget::zero());
        assert!(!d.measured);
        assert!(d.trials.is_empty());
        assert_ne!(d.kind, EngineKind::Auto);
        // n=100 < the fork-join threshold → sequential.
        assert_eq!(d.kind, EngineKind::Sequential);
    }

    #[test]
    fn cost_model_prefers_effective_for_scatter_free() {
        let mut rng = Rng::new(3);
        let coo = Coo::random_structurally_symmetric(5000, 3, false, &mut rng);
        let csr = Csr::from_coo(&coo);
        let plan = PlanBuilder::all(4).build(&csr);
        let f = Features::extract(&csr, &plan);
        assert_eq!(cost_model(&f), EngineKind::LocalBuffers(AccumMethod::Effective));
    }

    #[test]
    fn resolve_runs_once_then_hits_the_cache() {
        let (kernel, plan) = kernel_and_plan(120, 4, 2);
        let cache = DecisionCache::in_memory();
        let (d1, hit1) = resolve(&kernel, &plan, &TrialBudget::smoke(), &cache);
        assert!(!hit1);
        let (d2, hit2) = resolve(&kernel, &plan, &TrialBudget::smoke(), &cache);
        assert!(hit2, "second resolve of the same structure must not re-tune");
        assert_eq!(d1.kind, d2.kind);
        // A different thread count is a different decision.
        let plan3 = Arc::new(PlanBuilder::all(3).build(kernel.as_ref()));
        let (_, hit3) = resolve(&kernel, &plan3, &TrialBudget::smoke(), &cache);
        assert!(!hit3);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn single_thread_candidates_are_sequential_only() {
        assert_eq!(candidates(1), vec![EngineKind::Sequential]);
        assert!(candidates(4).contains(&EngineKind::Colorful));
        assert!(candidates(4).contains(&EngineKind::Atomic));
        assert!(!candidates(4).contains(&EngineKind::Auto));
        // One thread needs no analysis pieces; two need everything.
        assert_eq!(required_pieces(1), PlanPieces::default());
        assert_eq!(required_pieces(2), PlanPieces::all());
    }

    #[test]
    fn measured_budget_upgrades_a_cached_cost_model_decision() {
        let (kernel, plan) = kernel_and_plan(130, 5, 2);
        let cache = DecisionCache::in_memory();
        let (d0, hit0) = resolve(&kernel, &plan, &TrialBudget::zero(), &cache);
        assert!(!hit0 && !d0.measured);
        // Zero-budget callers keep hitting the heuristic entry...
        let (_, hit1) = resolve(&kernel, &plan, &TrialBudget::zero(), &cache);
        assert!(hit1);
        // ...but a measuring budget re-tunes instead of freezing it.
        let (d2, hit2) = resolve(&kernel, &plan, &TrialBudget::smoke(), &cache);
        assert!(!hit2 && d2.measured);
        // And the upgraded (measured) entry now satisfies everyone.
        let (d3, hit3) = resolve(&kernel, &plan, &TrialBudget::smoke(), &cache);
        assert!(hit3 && d3.measured);
    }
}
