//! Autotuner — measurement-driven per-matrix engine selection.
//!
//! The paper's headline result is that **no single strategy wins
//! everywhere**: local buffers beat coloring for most matrices, but the
//! best accumulation method varies with structure (§4). This subsystem
//! makes that observation operational instead of leaving the choice to
//! the caller:
//!
//! 1. [`Features::extract`] reads the structural signals the decision
//!    depends on (order, work, scatter ratio, write bandwidth, color and
//!    interval counts, partition balance) from a [`SpmvKernel`] and its
//!    full [`SpmvPlan`];
//! 2. [`tune`] runs short measured trials of every candidate engine —
//!    the paper's median-of-runs protocol
//!    ([`crate::metrics::median_and_spread_of_runs`]) under a
//!    configurable [`TrialBudget`] — and emits a [`Decision`];
//! 3. [`sweep`] adds the second dimension the paper's §4 scalability
//!    curves demand: the same trials repeated across a thread-count
//!    ladder ([`thread_ladder`]), because several matrices peak *below*
//!    the machine's core count — the winning `(engine, nthreads)` pair
//!    plus the full sweep surface land in the [`Decision`];
//! 4. [`tune_reordered`] / [`sweep_reordered`] add the *reorder* axis
//!    ([`candidates_with_reorder`]): under
//!    [`crate::reorder::ReorderPolicy::Measure`] every candidate also
//!    runs through the RCM ordering (timed behind a
//!    [`ReorderedEngine`], so the per-product permute/un-permute
//!    gathers count), and [`Decision::reorder`] records the winner's
//!    ordering;
//! 5. a zero budget skips the trials and falls back to the learned
//!    [`CostModel`] when one is supplied ([`resolve_with_model`]) and
//!    otherwise to [`cost_model`], a paper-derived heuristic over the
//!    same features — [`Decision::provenance`] records which answered;
//! 6. [`resolve`] / [`resolve_swept`] front the whole thing with a
//!    persistent [`DecisionCache`] keyed by (structure [`fingerprint`] ×
//!    thread budget), so a restarted service never re-tunes a known
//!    matrix. Fallback order: cache hit → model prediction → heuristic.
//!
//! [`crate::parallel::EngineKind::Auto`] is the routing-level entry
//! point: the coordinator resolves it here at registration time and the
//! workers only ever see concrete engines.

pub mod cache;
pub mod features;
pub mod model;

pub use cache::{decision_json, DecisionCache};
pub use features::{fingerprint, Features};
pub use model::{CostModel, CorpusRow, Prediction};

use crate::metrics;
use crate::parallel::{build_engine, AccumMethod, EngineKind, ParallelSpmv};
use crate::plan::{PlanBuilder, PlanCache, PlanPieces, SpmvPlan};
use crate::reorder::{self, Permutation, ReorderPolicy, ReorderedEngine};
use crate::sparse::SpmvKernel;
use std::sync::Arc;
use std::time::Instant;

/// How much measuring a tuning run may do: `runs` timed repetitions of
/// `products` back-to-back products per candidate engine (the paper's §4
/// protocol, scaled down). A zero budget means "no trials": the decision
/// comes from the learned [`CostModel`] (when supplied) or [`cost_model`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TrialBudget {
    pub runs: usize,
    pub products: usize,
}

impl Default for TrialBudget {
    fn default() -> Self {
        TrialBudget { runs: 3, products: 8 }
    }
}

impl TrialBudget {
    /// No measuring at all — [`tune`] answers from the cost model.
    pub fn zero() -> TrialBudget {
        TrialBudget { runs: 0, products: 0 }
    }

    /// Cheapest measured budget (CI smoke runs).
    pub fn smoke() -> TrialBudget {
        TrialBudget { runs: 1, products: 2 }
    }

    pub fn is_zero(&self) -> bool {
        self.runs == 0 || self.products == 0
    }
}

/// One candidate's measurement.
#[derive(Clone, Debug)]
pub struct TrialResult {
    pub kind: EngineKind,
    /// True when this trial ran through the RCM ordering (engine over
    /// the permuted kernel behind a [`ReorderedEngine`] wrapper, so the
    /// per-product permute/un-permute gathers are inside the timing).
    pub reordered: bool,
    /// Median seconds per product across the budgeted runs.
    pub seconds_per_product: f64,
    /// MAD across runs — how noisy the median is.
    pub mad_s: f64,
    /// Rate normalized by the kernel's work units ([`Features::work_flops`]).
    pub mflops: f64,
}

impl TrialResult {
    /// Display label: the engine kind, `reordered/`-prefixed when the
    /// trial ran through the RCM ordering.
    pub fn label(&self) -> String {
        if self.reordered {
            format!("reordered/{}", self.kind.label())
        } else {
            self.kind.label()
        }
    }
}

/// One rung of the thread-count ladder in a swept decision: every
/// candidate engine's measurement at `nthreads`.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub nthreads: usize,
    pub trials: Vec<TrialResult>,
}

impl SweepPoint {
    /// The fastest candidate at this thread count.
    pub fn best(&self) -> Option<&TrialResult> {
        self.trials
            .iter()
            .max_by(|a, b| a.mflops.partial_cmp(&b.mflops).expect("rates are finite"))
    }
}

/// Where a decision's pick came from — surfaced in service stats and
/// persisted with the entry, so a cache full of cold-start placeholders
/// is distinguishable from measured truth.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Provenance {
    /// Measured trials picked the winner (`Decision::measured`).
    Measured,
    /// The learned [`CostModel`] predicted it (zero-budget/cold-start).
    Model,
    /// The hand-written [`cost_model`] heuristic picked it.
    Heuristic,
}

impl Provenance {
    pub fn label(&self) -> &'static str {
        match self {
            Provenance::Measured => "measured",
            Provenance::Model => "model",
            Provenance::Heuristic => "heuristic",
        }
    }

    pub fn parse(s: &str) -> Option<Provenance> {
        match s {
            "measured" => Some(Provenance::Measured),
            "model" => Some(Provenance::Model),
            "heuristic" => Some(Provenance::Heuristic),
            _ => None,
        }
    }
}

/// The tuner's verdict for one matrix × thread budget.
#[derive(Clone, Debug)]
pub struct Decision {
    /// The winning concrete engine (never [`EngineKind::Auto`]).
    pub kind: EngineKind,
    /// True when the winner ran through the RCM ordering — the caller
    /// must execute via the permuted matrix with permute/un-permute per
    /// product ([`ReorderedEngine`] / [`crate::reorder::ReorderedLinOp`]).
    pub reorder: bool,
    /// The winner's measured rate (0 when `measured` is false).
    pub mflops: f64,
    /// False when the decision came from the model or the heuristic
    /// without trials.
    pub measured: bool,
    /// Which path picked the winner: measured trials, the learned
    /// [`CostModel`], or the [`cost_model`] heuristic. `Measured` iff
    /// `measured` is true.
    pub provenance: Provenance,
    /// Served-rate baseline (Mflop/s) the service records back into the
    /// entry after a drift re-tune (0 = none yet). Drift must be judged
    /// against *serving* reality — the trial rate is warm back-to-back
    /// products and therefore optimistic, and judging per-request
    /// serving against it re-triggers forever (a re-tune storm).
    pub served_mflops: f64,
    /// Wall-clock seconds the tuning run itself cost.
    pub tuned_s: f64,
    /// Structure fingerprint — the cache key, with `max_threads`.
    pub fingerprint: u64,
    /// Thread count the winning engine should run at.
    pub nthreads: usize,
    /// Thread budget the decision was tuned under — the second half of
    /// the cache key. A swept decision may pick `nthreads < max_threads`
    /// (the §4 curves: "more threads" is not monotone once memory
    /// bandwidth saturates); single-p decisions have the two equal.
    pub max_threads: usize,
    pub features: Features,
    /// The winning thread count's trials (every candidate at that p).
    pub trials: Vec<TrialResult>,
    /// Full (engine × nthreads) sweep surface; empty for single-p
    /// decisions and for entries loaded from a v1 cache file.
    pub sweep: Vec<SweepPoint>,
    /// The block-size axis: how many right-hand sides the winner should
    /// coalesce per product (`ParallelSpmv::spmv_multi`). 1 means plain
    /// SpMV; measured decisions pick the per-vector-rate argmax over
    /// [`BLOCK_LADDER`]. Entries from older cache files default to 1.
    pub block_k: usize,
    /// Per-vector Mflop/s of the winner at each trialled block size —
    /// `(k, rate)` pairs over [`BLOCK_LADDER`]; empty when unmeasured.
    pub block_rates: Vec<(usize, f64)>,
}

impl Decision {
    /// Display label of the winner: the engine kind, `reordered/`-
    /// prefixed when the decision executes through the RCM ordering —
    /// the single source for every log/stat that prints a decision.
    pub fn label(&self) -> String {
        if self.reorder {
            format!("reordered/{}", self.kind.label())
        } else {
            self.kind.label()
        }
    }
}

/// The candidate set for a thread count: every concrete engine that can
/// possibly win, including the sequential baseline (small matrices do not
/// amortize fork-join — the paper's §4.2 one-thread shortcut) and the
/// atomics baseline the paper dismisses (measurement, not folklore,
/// decides).
pub fn candidates(nthreads: usize) -> Vec<EngineKind> {
    let mut v = vec![EngineKind::Sequential];
    if nthreads > 1 {
        v.extend(EngineKind::all_local_buffers());
        v.push(EngineKind::Colorful);
        v.push(EngineKind::Atomic);
    }
    v
}

/// One (engine × ordering) candidate of the two-axis search: the
/// engine kinds of [`candidates`] crossed with whether the trial runs
/// through the RCM ordering.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Candidate {
    pub kind: EngineKind,
    pub reordered: bool,
}

/// [`candidates`] with the reorder axis: every kind plain, plus —
/// when `reorder` — every kind again through the RCM ordering, so the
/// tuner measures reorder-on vs reorder-off per matrix instead of
/// assuming either. (Reordered sequential is a real candidate: a
/// bandwidth win that needs no threads at all.)
pub fn candidates_with_reorder(nthreads: usize, reorder: bool) -> Vec<Candidate> {
    let mut v: Vec<Candidate> = candidates(nthreads)
        .into_iter()
        .map(|kind| Candidate { kind, reordered: false })
        .collect();
    if reorder {
        v.extend(
            candidates(nthreads).into_iter().map(|kind| Candidate { kind, reordered: true }),
        );
    }
    v
}

/// Plan pieces the tuner needs at a thread count — the union over
/// [`candidates`]. Everything at p ≥ 2; only the base partition at
/// p = 1, where the sole candidate is the sequential sweep and paying
/// for conflict coloring would be pure waste.
pub fn required_pieces(nthreads: usize) -> PlanPieces {
    let mut need = PlanPieces::default();
    for kind in candidates(nthreads) {
        need = need.union(PlanPieces::for_kind(kind));
    }
    need
}

/// A [`sweep`] plan provider backed by a shared [`PlanCache`]: one
/// analysis per (key × thread count), each plan built with exactly
/// [`required_pieces`]`(p)` — the contract [`sweep`] asserts. Every
/// sweeping call site (service registration, background re-tune, CLI,
/// figure harness, benches) goes through this so the contract cannot be
/// broken by a hand-rolled closure.
pub fn cached_plan_provider<'a>(
    plans: &'a PlanCache,
    key: &'a str,
    kernel: &'a Arc<dyn SpmvKernel>,
) -> impl FnMut(usize) -> Arc<SpmvPlan> + 'a {
    move |p: usize| {
        let builder = PlanBuilder::new(p).with_pieces(required_pieces(p));
        plans.get_or_build(key, kernel.as_ref(), builder)
    }
}

/// The thread-count ladder a [`sweep`] trials: 1, 2, 4, … doubling up to
/// and always including `max` (the paper's §4 scalability axis, scaled
/// to the caller's thread budget). `max == 0` is treated as 1.
pub fn thread_ladder(max: usize) -> Vec<usize> {
    let max = max.max(1);
    let mut v = Vec::new();
    let mut p = 1;
    while p < max {
        v.push(p);
        p *= 2;
    }
    v.push(max);
    v
}

/// Paper-derived heuristic over structural features — the zero-budget
/// fallback, also used by workers racing a registration-time tuning run.
///
/// * Small orders don't amortize fork-join: sequential (§4.2).
/// * A scatter-free kernel (CSR-like) has block-exact effective ranges,
///   so `local-buffers/effective` degenerates to the ideal row split.
/// * Almost-conflict-free patterns (≤ 2 colors) suit the colorful
///   schedule: barely any serialization between classes (§3.2).
/// * Otherwise local buffers win "for most matrices" (§4.3); a fine
///   interval decomposition indicates scattered write ranges where the
///   interval accumulation amortizes best, else effective accumulation.
pub fn cost_model(f: &Features) -> EngineKind {
    if f.nthreads <= 1 || f.n < 2048 {
        return EngineKind::Sequential;
    }
    if f.scatter_ratio == 0.0 {
        return EngineKind::LocalBuffers(AccumMethod::Effective);
    }
    if f.colors <= 2 {
        return EngineKind::Colorful;
    }
    if f.intervals > 4 * f.nthreads.max(1) {
        EngineKind::LocalBuffers(AccumMethod::Interval)
    } else {
        EngineKind::LocalBuffers(AccumMethod::Effective)
    }
}

/// Run the measured trials and pick a winner. `plan` must carry the
/// pieces every candidate at its thread count borrows
/// ([`required_pieces`]; `PlanBuilder::all` always suffices); panics
/// otherwise (programming error, same contract as [`build_engine`]).
pub fn tune(kernel: &Arc<dyn SpmvKernel>, plan: &Arc<SpmvPlan>, budget: &TrialBudget) -> Decision {
    tune_with_fingerprint(
        kernel,
        plan,
        budget,
        fingerprint(kernel.as_ref()),
        ReorderPolicy::Never,
        None,
    )
}

/// [`tune`] with the reorder axis: under [`ReorderPolicy::Measure`] the
/// candidate set doubles ([`candidates_with_reorder`]) and the RCM
/// ordering competes on measured rate; under [`ReorderPolicy::Always`]
/// only the reordered candidates run (falling back to plain when the
/// kernel cannot permute or RCM is the identity).
pub fn tune_reordered(
    kernel: &Arc<dyn SpmvKernel>,
    plan: &Arc<SpmvPlan>,
    budget: &TrialBudget,
    policy: ReorderPolicy,
) -> Decision {
    tune_with_fingerprint(kernel, plan, budget, fingerprint(kernel.as_ref()), policy, None)
}

/// [`tune`] with a caller-supplied fingerprint, so [`resolve`] — which
/// already hashed the structure for its cache lookup — does not pay the
/// O(nnz) pass twice on a miss.
fn tune_with_fingerprint(
    kernel: &Arc<dyn SpmvKernel>,
    plan: &Arc<SpmvPlan>,
    budget: &TrialBudget,
    fp: u64,
    policy: ReorderPolicy,
    model: Option<&CostModel>,
) -> Decision {
    assert!(
        plan.pieces.covers(required_pieces(plan.nthreads)),
        "the tuner trials every candidate engine: build the plan with \
         PlanBuilder::all or tuner::required_pieces"
    );
    let t0 = Instant::now();
    let features = Features::extract(kernel.as_ref(), plan);
    if budget.is_zero() {
        // Cold-start fallback order: learned model, then the heuristic.
        // (A model prediction already honours the reorder policy; on
        // the heuristic path the only honest "always" is the caller's
        // forced ordering — Measure degrades to plain.)
        let (kind, reorder, provenance) = match model.and_then(|m| m.predict(&features, policy)) {
            Some(p) => (p.kind, p.reordered, Provenance::Model),
            None => (cost_model(&features), policy == ReorderPolicy::Always, Provenance::Heuristic),
        };
        let block_k = match (provenance, model) {
            (Provenance::Model, Some(m)) => m.predict_block_k(&features, 8),
            _ => heuristic_block_k(&features),
        };
        return Decision {
            kind,
            reorder,
            mflops: 0.0,
            measured: false,
            provenance,
            served_mflops: 0.0,
            tuned_s: t0.elapsed().as_secs_f64(),
            fingerprint: fp,
            nthreads: plan.nthreads,
            max_threads: plan.nthreads,
            features,
            trials: Vec::new(),
            sweep: Vec::new(),
            block_k,
            block_rates: Vec::new(),
        };
    }
    let work = features.work_flops;
    let rctx = if policy == ReorderPolicy::Never { None } else { reorder_context(kernel, plan) };
    let cands = candidates_with_reorder(plan.nthreads, rctx.is_some());
    let mut trials = Vec::new();
    if policy != ReorderPolicy::Always || rctx.is_none() {
        let plain: Vec<EngineKind> =
            cands.iter().filter(|c| !c.reordered).map(|c| c.kind).collect();
        trials.extend(measure_candidates(kernel, plan, budget, work, &plain));
    }
    if let Some((pk, pplan, perm)) = &rctx {
        let reord: Vec<EngineKind> =
            cands.iter().filter(|c| c.reordered).map(|c| c.kind).collect();
        trials.extend(measure_reordered_candidates(pk, pplan, perm, budget, work, &reord));
    }
    let best = best_trial(&trials).clone();
    let block_rates =
        block_axis_for_winner(kernel, plan, &rctx, best.kind, best.reordered, budget, work);
    let block_k = best_block_k(&block_rates);
    Decision {
        kind: best.kind,
        reorder: best.reordered,
        mflops: best.mflops,
        measured: true,
        provenance: Provenance::Measured,
        served_mflops: 0.0,
        tuned_s: t0.elapsed().as_secs_f64(),
        fingerprint: fp,
        nthreads: plan.nthreads,
        max_threads: plan.nthreads,
        features,
        trials,
        sweep: Vec::new(),
        block_k,
        block_rates,
    }
}

/// Measure every kind in `kinds` over the shared plan. Each engine runs
/// one *untimed* warm-up product first: the first timed run would
/// otherwise pay pool spin-up and cold caches, biasing the comparison
/// against whichever candidate happens to run first (and, under a
/// one-run budget where the median cannot shrug the cold run off,
/// against every pool-backed engine).
fn measure_candidates(
    kernel: &Arc<dyn SpmvKernel>,
    plan: &Arc<SpmvPlan>,
    budget: &TrialBudget,
    work: usize,
    kinds: &[EngineKind],
) -> Vec<TrialResult> {
    let n = kernel.dim();
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.001).sin()).collect();
    let mut y = vec![0.0; n];
    let mut trials = Vec::with_capacity(kinds.len());
    for &kind in kinds {
        let mut engine = build_engine(kind, kernel.clone(), plan.clone());
        engine.spmv(&x, &mut y);
        let trial_span = crate::obs::phase(crate::obs::Phase::TuneTrial);
        let (per, mad) = metrics::median_and_spread_of_runs(budget.runs, budget.products, || {
            engine.spmv(&x, &mut y)
        });
        drop(trial_span);
        trials.push(TrialResult {
            kind,
            reordered: false,
            seconds_per_product: per,
            mad_s: mad,
            mflops: metrics::mflops(work, per),
        });
    }
    trials
}

/// The reorder trial context: the permuted kernel, a plan built for it,
/// and the permutation — or `None` when the kernel cannot permute
/// (formats without [`SpmvKernel::permuted`]) or RCM cannot tighten the
/// band (an already well-ordered matrix: reordering would only add the
/// per-product gather cost, so there is nothing worth measuring).
/// Prefers the analysis the plan's reorder stage already computed.
fn reorder_context(
    kernel: &Arc<dyn SpmvKernel>,
    plan: &SpmvPlan,
) -> Option<(Arc<dyn SpmvKernel>, Arc<SpmvPlan>, Arc<Permutation>)> {
    let (perm, hbw_before, hbw_after) = match &plan.reorder {
        Some(r) => (r.perm.clone(), r.hbw_before, r.hbw_after),
        None => {
            let r = reorder::analyze(kernel.as_ref());
            (r.perm, r.hbw_before, r.hbw_after)
        }
    };
    if hbw_after >= hbw_before {
        return None;
    }
    let permuted = kernel.permuted(&perm)?;
    let pieces = PlanPieces { reorder: false, ..plan.pieces };
    let pplan =
        Arc::new(PlanBuilder::new(plan.nthreads).with_pieces(pieces).build(permuted.as_ref()));
    Some((permuted, pplan, perm))
}

/// [`measure_candidates`] through the RCM ordering: engines are built
/// over the permuted kernel and timed behind a [`ReorderedEngine`], so
/// the per-product permute/un-permute gathers count against the
/// reordered candidates — the comparison with the plain trials is
/// end-to-end honest.
fn measure_reordered_candidates(
    permuted: &Arc<dyn SpmvKernel>,
    plan: &Arc<SpmvPlan>,
    perm: &Arc<Permutation>,
    budget: &TrialBudget,
    work: usize,
    kinds: &[EngineKind],
) -> Vec<TrialResult> {
    let n = permuted.dim();
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.001).sin()).collect();
    let mut y = vec![0.0; n];
    let mut trials = Vec::with_capacity(kinds.len());
    for &kind in kinds {
        let inner = build_engine(kind, permuted.clone(), plan.clone());
        let mut engine = ReorderedEngine::new(inner, perm.clone());
        engine.spmv(&x, &mut y); // untimed warm-up, as for plain trials
        let trial_span = crate::obs::phase(crate::obs::Phase::TuneTrial);
        let (per, mad) = metrics::median_and_spread_of_runs(budget.runs, budget.products, || {
            engine.spmv(&x, &mut y)
        });
        drop(trial_span);
        trials.push(TrialResult {
            kind,
            reordered: true,
            seconds_per_product: per,
            mad_s: mad,
            mflops: metrics::mflops(work, per),
        });
    }
    trials
}

fn best_trial(trials: &[TrialResult]) -> &TrialResult {
    trials
        .iter()
        .max_by(|a, b| a.mflops.partial_cmp(&b.mflops).expect("rates are finite"))
        .expect("candidates is never empty")
}

/// The block-size ladder the tuner trials on the winning engine: how
/// many right-hand sides one blocked product coalesces. SpMV is
/// bandwidth-bound, so reading the matrix once for k panels usually
/// beats k serial products once k amortizes the extra x/y traffic.
pub const BLOCK_LADDER: [usize; 4] = [1, 2, 4, 8];

/// Zero-budget fallback for the block axis: large matrices are
/// bandwidth-bound (the blocked product's win), small ones live in
/// cache where the extra panel traffic can cost more than it saves.
pub fn heuristic_block_k(f: &Features) -> usize {
    if f.n >= 2048 {
        4
    } else {
        1
    }
}

/// Time the winner's k-wide product over [`BLOCK_LADDER`], returning
/// `(k, per-vector Mflop/s)` — one blocked product computes k vectors,
/// so the honest comparison normalizes by `work · k`.
fn measure_block_axis(
    engine: &mut dyn ParallelSpmv,
    n: usize,
    budget: &TrialBudget,
    work: usize,
) -> Vec<(usize, f64)> {
    let mut rates = Vec::with_capacity(BLOCK_LADDER.len());
    for &k in BLOCK_LADDER.iter() {
        let x: Vec<f64> = (0..n * k).map(|i| (i as f64 * 0.001).sin()).collect();
        let mut y = vec![0.0; n * k];
        engine.spmv_multi(&x, &mut y, k); // untimed warm-up
        let trial_span = crate::obs::phase(crate::obs::Phase::TuneTrial);
        let (per, _) = metrics::median_and_spread_of_runs(budget.runs, budget.products, || {
            engine.spmv_multi(&x, &mut y, k)
        });
        drop(trial_span);
        rates.push((k, metrics::mflops(work * k, per)));
    }
    rates
}

/// The per-vector-rate argmax of a measured block axis (1 when empty).
pub fn best_block_k(rates: &[(usize, f64)]) -> usize {
    rates
        .iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).expect("rates are finite"))
        .map(|&(k, _)| k)
        .unwrap_or(1)
}

/// Build the decision's winning engine (reordered or plain) and measure
/// its block axis. `rctx` must be the same reorder context the winner
/// was trialled in when `best.reordered`.
fn block_axis_for_winner(
    kernel: &Arc<dyn SpmvKernel>,
    plan: &Arc<SpmvPlan>,
    rctx: &Option<(Arc<dyn SpmvKernel>, Arc<SpmvPlan>, Arc<Permutation>)>,
    kind: EngineKind,
    reordered: bool,
    budget: &TrialBudget,
    work: usize,
) -> Vec<(usize, f64)> {
    let n = kernel.dim();
    if reordered {
        if let Some((pk, pplan, perm)) = rctx {
            let inner = build_engine(kind, pk.clone(), pplan.clone());
            let mut engine = ReorderedEngine::new(inner, perm.clone());
            return measure_block_axis(&mut engine, n, budget, work);
        }
    }
    let mut engine = build_engine(kind, kernel.clone(), plan.clone());
    measure_block_axis(engine.as_mut(), n, budget, work)
}

/// Two-dimensional tuning: trial every candidate engine at every thread
/// count of `ladder`, returning the `(engine, nthreads)` argmax plus the
/// full sweep surface. `plan_for(p)` supplies the shared plan at p —
/// typically [`crate::plan::PlanCache::get_or_build`], so sweeping a
/// registered matrix reuses one analysis per thread count; each returned
/// plan must be built at p and cover [`required_pieces`]`(p)`.
///
/// The paper's §4 scalability curves motivate the second dimension:
/// several matrices peak below the machine's core count (memory
/// bandwidth saturates first), so tuning the engine at one fixed p
/// leaves rate on the table — measurement must pick p too.
pub fn sweep(
    kernel: &Arc<dyn SpmvKernel>,
    ladder: &[usize],
    budget: &TrialBudget,
    plan_for: &mut dyn FnMut(usize) -> Arc<SpmvPlan>,
) -> Decision {
    sweep_with_fingerprint(
        kernel,
        ladder,
        budget,
        plan_for,
        fingerprint(kernel.as_ref()),
        ReorderPolicy::Never,
        None,
    )
}

/// [`sweep`] with the reorder axis: every ladder rung trials the plain
/// *and* the RCM-reordered candidates, so reorder-on vs reorder-off is
/// measured per matrix across the whole (engine × p) surface. The
/// permuted kernel's per-rung plans are built locally (they belong to
/// the reordered structure, not the caller's plan cache key).
pub fn sweep_reordered(
    kernel: &Arc<dyn SpmvKernel>,
    ladder: &[usize],
    budget: &TrialBudget,
    plan_for: &mut dyn FnMut(usize) -> Arc<SpmvPlan>,
    policy: ReorderPolicy,
) -> Decision {
    sweep_with_fingerprint(
        kernel,
        ladder,
        budget,
        plan_for,
        fingerprint(kernel.as_ref()),
        policy,
        None,
    )
}

fn sweep_with_fingerprint(
    kernel: &Arc<dyn SpmvKernel>,
    ladder: &[usize],
    budget: &TrialBudget,
    plan_for: &mut dyn FnMut(usize) -> Arc<SpmvPlan>,
    fp: u64,
    policy: ReorderPolicy,
    model: Option<&CostModel>,
) -> Decision {
    assert!(!ladder.is_empty(), "thread ladder must name at least one thread count");
    let max = ladder.iter().copied().max().unwrap_or(1);
    let t0 = Instant::now();
    let plan_max = plan_for(max);
    assert!(
        plan_max.nthreads == max && plan_max.pieces.covers(required_pieces(max)),
        "plan_for must honour the requested thread count and tuner::required_pieces"
    );
    let features = Features::extract(kernel.as_ref(), &plan_max);
    if budget.is_zero() {
        // Cold-start fallback order: learned model (which also picks
        // the thread count through its per-rung rate regressors), then
        // the heuristic, which has no p axis — sequential runs at 1
        // thread, everything else at the full budget.
        let (kind, reorder, nthreads, provenance) =
            match model.and_then(|m| m.predict(&features, policy).map(|p| (m, p))) {
                Some((m, p)) => {
                    let nt = m.predict_threads(&features, p.kind, max);
                    (p.kind, p.reordered, nt, Provenance::Model)
                }
                None => {
                    let kind = cost_model(&features);
                    let nthreads = if kind == EngineKind::Sequential { 1 } else { max };
                    (kind, policy == ReorderPolicy::Always, nthreads, Provenance::Heuristic)
                }
            };
        let block_k = match (provenance, model) {
            (Provenance::Model, Some(m)) => m.predict_block_k(&features, 8),
            _ => heuristic_block_k(&features),
        };
        return Decision {
            kind,
            reorder,
            mflops: 0.0,
            measured: false,
            provenance,
            served_mflops: 0.0,
            tuned_s: t0.elapsed().as_secs_f64(),
            fingerprint: fp,
            nthreads,
            max_threads: max,
            features,
            trials: Vec::new(),
            sweep: Vec::new(),
            block_k,
            block_rates: Vec::new(),
        };
    }
    let work = features.work_flops;
    // Reorder context shared across rungs: the permutation and permuted
    // kernel are p-independent; only the plan is rebuilt per rung.
    let rctx = if policy == ReorderPolicy::Never {
        None
    } else {
        reorder_context(kernel, &plan_max)
    };
    let skip_plain = policy == ReorderPolicy::Always && rctx.is_some();
    let mut sweep: Vec<SweepPoint> = Vec::with_capacity(ladder.len());
    // The sequential sweeps (plain and reordered) ignore the plan's
    // thread count, so one measurement each — taken at the first rung —
    // serves every rung; without this the usually-slowest candidates
    // would be re-timed per rung.
    let mut seq_trial: Option<TrialResult> = None;
    let mut seq_trial_reordered: Option<TrialResult> = None;
    for &p in ladder {
        if sweep.iter().any(|pt| pt.nthreads == p) {
            continue; // a duplicated rung buys no information
        }
        let plan = if p == max { plan_max.clone() } else { plan_for(p) };
        assert!(
            plan.nthreads == p && plan.pieces.covers(required_pieces(p)),
            "plan_for must honour the requested thread count and tuner::required_pieces"
        );
        let mut trials: Vec<TrialResult> = Vec::new();
        if !skip_plain {
            let mut kinds = candidates(p);
            if seq_trial.is_some() {
                kinds.retain(|k| *k != EngineKind::Sequential);
            }
            trials = measure_candidates(kernel, &plan, budget, work, &kinds);
            match &seq_trial {
                Some(t) => trials.insert(0, t.clone()),
                None => {
                    seq_trial =
                        trials.iter().find(|t| t.kind == EngineKind::Sequential).cloned();
                }
            }
        }
        if let Some((pk, pplan_max, perm)) = &rctx {
            let pplan = if p == max {
                pplan_max.clone()
            } else {
                Arc::new(
                    PlanBuilder::new(p).with_pieces(required_pieces(p)).build(pk.as_ref()),
                )
            };
            let mut kinds = candidates(p);
            if seq_trial_reordered.is_some() {
                kinds.retain(|k| *k != EngineKind::Sequential);
            }
            let start = trials.len();
            trials.extend(measure_reordered_candidates(pk, &pplan, perm, budget, work, &kinds));
            match &seq_trial_reordered {
                Some(t) => trials.insert(start, t.clone()),
                None => {
                    seq_trial_reordered = trials[start..]
                        .iter()
                        .find(|t| t.kind == EngineKind::Sequential)
                        .cloned();
                }
            }
        }
        sweep.push(SweepPoint { nthreads: p, trials });
    }
    let (best_p, best_kind, best_reorder, best_mflops) = sweep
        .iter()
        .map(|pt| {
            let b = pt.best().expect("candidates is never empty");
            (pt.nthreads, b.kind, b.reordered, b.mflops)
        })
        .max_by(|a, b| a.3.partial_cmp(&b.3).expect("rates are finite"))
        .expect("ladder is never empty");
    let trials = sweep
        .iter()
        .find(|pt| pt.nthreads == best_p)
        .expect("winner rung exists")
        .trials
        .clone();
    // Block axis at the winning rung: the engine and its plan at best_p.
    let plan_best = if best_p == max { plan_max.clone() } else { plan_for(best_p) };
    let rctx_best = rctx.as_ref().map(|(pk, pplan_max, perm)| {
        let pplan = if best_p == max {
            pplan_max.clone()
        } else {
            Arc::new(
                PlanBuilder::new(best_p).with_pieces(required_pieces(best_p)).build(pk.as_ref()),
            )
        };
        (pk.clone(), pplan, perm.clone())
    });
    let block_rates = block_axis_for_winner(
        kernel,
        &plan_best,
        &rctx_best,
        best_kind,
        best_reorder,
        budget,
        work,
    );
    let block_k = best_block_k(&block_rates);
    Decision {
        kind: best_kind,
        reorder: best_reorder,
        mflops: best_mflops,
        measured: true,
        provenance: Provenance::Measured,
        served_mflops: 0.0,
        tuned_s: t0.elapsed().as_secs_f64(),
        fingerprint: fp,
        nthreads: best_p,
        max_threads: max,
        features,
        trials,
        sweep,
        block_k,
        block_rates,
    }
}

/// Cache-fronted [`tune`]: returns the decision plus whether it came
/// from the cache (`true` = zero new trials were run).
///
/// A cached *unmeasured* (cost-model) decision does not satisfy a caller
/// that brought a measuring budget: it is re-tuned and the cache entry
/// upgraded — otherwise one zero-budget call would freeze the heuristic
/// pick forever. A cached *swept* decision satisfies a single-p caller
/// through [`single_p_view`]: the caller asked for `plan.nthreads`
/// threads, so it gets that rung's winner, not the sweep's global
/// (possibly lower-p) pick — re-tuning would let sweeping and
/// non-sweeping callers endlessly overwrite each other's entries.
/// A cached *measured* entry satisfies any reorder policy — re-tuning
/// on every policy change would thrash the cache — with one exception:
/// `Never` is an explicit off switch, so a cached reordered winner is
/// demoted through [`never_view`] instead of silently re-enabling the
/// permute/un-permute path. Callers wanting fresh reorder trials bring
/// a fresh cache file.
pub fn resolve(
    kernel: &Arc<dyn SpmvKernel>,
    plan: &Arc<SpmvPlan>,
    budget: &TrialBudget,
    cache: &DecisionCache,
    policy: ReorderPolicy,
) -> (Decision, bool) {
    resolve_with_model(kernel, plan, budget, cache, policy, None)
}

/// [`resolve`] with the learned cost model in the fallback chain: on a
/// cache miss with a zero budget the model — when supplied — answers
/// before the hand-written heuristic ([`Decision::provenance`] records
/// which). With a measuring budget the model is ignored: real trials
/// beat any prediction.
pub fn resolve_with_model(
    kernel: &Arc<dyn SpmvKernel>,
    plan: &Arc<SpmvPlan>,
    budget: &TrialBudget,
    cache: &DecisionCache,
    policy: ReorderPolicy,
    model: Option<&CostModel>,
) -> (Decision, bool) {
    let fp = fingerprint(kernel.as_ref());
    if let Some(d) = cache.peek(fp, plan.nthreads) {
        if (d.measured || budget.is_zero()) && !placeholder_outranked(&d, model, policy, false) {
            cache.record(true);
            return (never_view(single_p_view(d, plan.nthreads), policy), true);
        }
    }
    cache.record(false);
    let d = tune_with_fingerprint(kernel, plan, budget, fp, policy, model);
    cache.put(d.clone());
    (d, false)
}

/// Should a cached *placeholder* (unmeasured entry) be re-answered for
/// this caller instead of served? Measured entries always stand — real
/// trials beat predictions. Placeholders yield in three cases:
///
/// * the recorded ordering is **incompatible with the caller's forced
///   policy** — a reordered pick under `Never` (`never_view` could only
///   strip the flag while keeping an engine chosen *for* reordered
///   execution; the model's plain-class pick can be a different engine
///   entirely) or a plain pick under `Always` (service workers execute
///   the resolved decision's flag, so serving it would silently disable
///   the forced RCM ordering);
/// * a **heuristic** placeholder meets a model that can actually answer
///   under the caller's policy: the fallback order (cache → model →
///   heuristic) demands the upgrade;
/// * a **model** placeholder meets a model whose prediction *under the
///   caller's policy* disagrees with the recorded pick — e.g. a plain
///   entry written by a `Never` caller met by a `Measure` caller whose
///   prediction is a reordered class. Same-policy callers always agree
///   (the model is deterministic), so this cannot churn; cross-policy
///   callers sharing one cache each re-answer at *registration* time —
///   the returned decision, not the cache entry, is what each service
///   serves by.
///
/// All checks run against the entry's own recorded features — cheap, a
/// few dot products — and a model that would decline (e.g. trained only
/// on reordered winners, asked under `Never`) never invalidates an
/// entry just to have the miss path write an identical one back.
/// `check_threads` is set by the swept resolver, where the model also
/// picks `nthreads`: a retrained model's rung regressors moving the
/// thread pick must re-answer a Model placeholder even when the engine
/// class is unchanged. The single-p resolver passes false — its thread
/// count is the caller's plan, not the model's to move.
fn placeholder_outranked(
    d: &Decision,
    model: Option<&CostModel>,
    policy: ReorderPolicy,
    check_threads: bool,
) -> bool {
    if d.measured {
        return false;
    }
    if (policy == ReorderPolicy::Never && d.reorder)
        || (policy == ReorderPolicy::Always && !d.reorder)
    {
        return true;
    }
    match d.provenance {
        Provenance::Measured => false,
        Provenance::Heuristic => {
            model.is_some_and(|m| m.predict(&d.features, policy).is_some())
        }
        Provenance::Model => model.is_some_and(|m| {
            m.predict(&d.features, policy).is_some_and(|p| {
                p.kind != d.kind
                    || p.reordered != d.reorder
                    || (check_threads
                        && m.predict_threads(&d.features, p.kind, d.max_threads) != d.nthreads)
            })
        }),
    }
}

/// A `Never` caller's view of a cached decision: reordered execution is
/// an opt-in, so a cached reordered winner is demoted to the best
/// *plain* measurement — searched across the whole sweep surface when
/// one was recorded (the plain optimum may sit at a different thread
/// count than the reordered winner). An entry written under `Always`
/// has no plain trials at all: the engine pick is kept (every engine
/// runs correctly in the given ordering) but the recorded rate is
/// cleared, since it was measured through the reordering and would
/// otherwise arm the drift detector against an unreachable baseline.
fn never_view(mut d: Decision, policy: ReorderPolicy) -> Decision {
    if policy != ReorderPolicy::Never || !d.reorder {
        return d;
    }
    let mut best: Option<(usize, EngineKind, f64)> = None;
    let mut consider = |p: usize, t: &TrialResult| {
        if !t.reordered && best.map_or(true, |(_, _, m)| t.mflops > m) {
            best = Some((p, t.kind, t.mflops));
        }
    };
    if d.sweep.is_empty() {
        for t in &d.trials {
            consider(d.nthreads, t);
        }
    } else {
        for pt in &d.sweep {
            for t in &pt.trials {
                consider(pt.nthreads, t);
            }
        }
    }
    match best {
        Some((p, kind, mflops)) => {
            d.kind = kind;
            d.mflops = mflops;
            d.nthreads = p;
        }
        None => d.mflops = 0.0,
    }
    d.reorder = false;
    d
}

/// A single-p caller's view of a cached decision. Swept entries answer
/// with their rung at exactly `p` — the cache key's `max_threads` *is*
/// the caller's thread count, so that rung was measured — which keeps
/// the caller's thread-count contract (`RoutePolicy::threads` means "run
/// at p" unless `sweep_threads` opted into per-matrix picks). Single-p
/// entries, and a sweep whose winner already sits at `p`, pass through
/// unchanged; a malformed surface with no rung at `p` (hand-edited
/// file) is served as recorded.
fn single_p_view(d: Decision, p: usize) -> Decision {
    if d.sweep.is_empty() || d.nthreads == p {
        return d;
    }
    let best = d
        .sweep
        .iter()
        .find(|pt| pt.nthreads == p)
        .and_then(|pt| pt.best().map(|b| (b.kind, b.reordered, b.mflops, pt.trials.clone())));
    match best {
        Some((kind, reorder, mflops, trials)) => {
            Decision { kind, reorder, mflops, nthreads: p, trials, ..d }
        }
        None => d,
    }
}

/// Cache-fronted [`sweep`], keyed by (fingerprint × the ladder's max
/// thread count). Same upgrade ladder as [`resolve`], one rung higher:
/// an unmeasured entry never satisfies a measuring caller, and a
/// measured *single-p* entry (a v1 cache file, or a plain [`tune`] at
/// the same thread budget) does not satisfy a caller asking for the
/// thread sweep — it is re-swept and the entry upgraded in place.
pub fn resolve_swept(
    kernel: &Arc<dyn SpmvKernel>,
    ladder: &[usize],
    budget: &TrialBudget,
    cache: &DecisionCache,
    plan_for: &mut dyn FnMut(usize) -> Arc<SpmvPlan>,
    policy: ReorderPolicy,
) -> (Decision, bool) {
    resolve_swept_with_model(kernel, ladder, budget, cache, plan_for, policy, None)
}

/// [`resolve_swept`] with the learned cost model in the fallback chain
/// (see [`resolve_with_model`]): on a zero-budget miss the model picks
/// the engine *and* — through its per-rung rate regressors — the
/// thread count.
pub fn resolve_swept_with_model(
    kernel: &Arc<dyn SpmvKernel>,
    ladder: &[usize],
    budget: &TrialBudget,
    cache: &DecisionCache,
    plan_for: &mut dyn FnMut(usize) -> Arc<SpmvPlan>,
    policy: ReorderPolicy,
    model: Option<&CostModel>,
) -> (Decision, bool) {
    let fp = fingerprint(kernel.as_ref());
    let max = ladder.iter().copied().max().unwrap_or(1);
    if let Some(d) = cache.peek(fp, max) {
        if (budget.is_zero() || (d.measured && !d.sweep.is_empty()))
            && !placeholder_outranked(&d, model, policy, true)
        {
            cache.record(true);
            return (never_view(d, policy), true);
        }
    }
    cache.record(false);
    let d = sweep_with_fingerprint(kernel, ladder, budget, plan_for, fp, policy, model);
    cache.put(d.clone());
    (d, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::PlanBuilder;
    use crate::sparse::{Coo, Csr, Csrc};
    use crate::util::Rng;

    fn kernel_and_plan(n: usize, seed: u64, p: usize) -> (Arc<dyn SpmvKernel>, Arc<SpmvPlan>) {
        let mut rng = Rng::new(seed);
        let coo = Coo::random_structurally_symmetric(n, 4, false, &mut rng);
        let kernel: Arc<dyn SpmvKernel> = Arc::new(Csrc::from_coo(&coo).unwrap());
        let plan = Arc::new(PlanBuilder::all(p).build(kernel.as_ref()));
        (kernel, plan)
    }

    #[test]
    fn tune_picks_a_measured_concrete_winner() {
        let (kernel, plan) = kernel_and_plan(150, 1, 2);
        let d = tune(&kernel, &plan, &TrialBudget::smoke());
        assert!(d.measured);
        assert!(!d.reorder, "plain tune never picks the reordered axis");
        assert!(d.trials.iter().all(|t| !t.reordered));
        assert_ne!(d.kind, EngineKind::Auto);
        assert_eq!(d.trials.len(), candidates(2).len());
        assert!(d.mflops > 0.0);
        assert!(d.tuned_s > 0.0);
        // The winner really is the argmax of the trials.
        let best = d.trials.iter().map(|t| t.mflops).fold(0.0, f64::max);
        assert_eq!(d.mflops, best);
        assert_eq!(d.nthreads, 2);
        // Single-p decisions: the thread budget equals the pick, and
        // there is no sweep surface.
        assert_eq!(d.max_threads, 2);
        assert!(d.sweep.is_empty());
        assert_eq!(d.fingerprint, fingerprint(kernel.as_ref()));
        // A measured decision carries the whole block axis: one rate
        // per ladder width, and a winner drawn from the ladder.
        assert_eq!(d.block_rates.len(), BLOCK_LADDER.len());
        assert!(BLOCK_LADDER.contains(&d.block_k));
        assert!(d.block_rates.iter().all(|&(_, r)| r > 0.0));
        let (bk, _) = d
            .block_rates
            .iter()
            .copied()
            .fold((1, 0.0), |a, b| if b.1 > a.1 { b } else { a });
        assert_eq!(d.block_k, bk, "block_k is the argmax of its own axis");
    }

    #[test]
    fn thread_ladder_doubles_up_to_max() {
        assert_eq!(thread_ladder(1), vec![1]);
        assert_eq!(thread_ladder(2), vec![1, 2]);
        assert_eq!(thread_ladder(4), vec![1, 2, 4]);
        assert_eq!(thread_ladder(6), vec![1, 2, 4, 6]);
        assert_eq!(thread_ladder(0), vec![1], "zero budget degrades to one thread");
    }

    #[test]
    fn sweep_picks_the_global_argmax_over_engines_and_threads() {
        let (kernel, _) = kernel_and_plan(150, 6, 2);
        let plans = crate::plan::PlanCache::new();
        let ladder = thread_ladder(2);
        let mut plan_for = cached_plan_provider(&plans, "m", &kernel);
        let d = sweep(&kernel, &ladder, &TrialBudget::smoke(), &mut plan_for);
        assert!(d.measured);
        assert_eq!(d.max_threads, 2);
        assert_eq!(d.sweep.len(), 2, "one point per ladder rung");
        assert_eq!(d.sweep[0].nthreads, 1);
        assert_eq!(d.sweep[0].trials.len(), candidates(1).len());
        assert_eq!(d.sweep[1].nthreads, 2);
        assert_eq!(d.sweep[1].trials.len(), candidates(2).len());
        assert!(d.nthreads == 1 || d.nthreads == 2);
        // The decision really is the argmax over the whole surface, and
        // `trials` mirrors the winning rung.
        let best = d
            .sweep
            .iter()
            .flat_map(|pt| pt.trials.iter().map(|t| t.mflops))
            .fold(0.0, f64::max);
        assert_eq!(d.mflops, best);
        let rung = d.sweep.iter().find(|pt| pt.nthreads == d.nthreads).unwrap();
        assert!(rung.trials.iter().any(|t| t.kind == d.kind && t.mflops == d.mflops));
        // One shared analysis per rung, no more.
        assert_eq!(plans.builds(), 2);
        // The sweep winner is re-measured over the block ladder too.
        assert_eq!(d.block_rates.len(), BLOCK_LADDER.len());
        assert!(BLOCK_LADDER.contains(&d.block_k));
    }

    #[test]
    fn sweep_zero_budget_answers_from_cost_model() {
        let (kernel, _) = kernel_and_plan(100, 7, 3);
        let plans = crate::plan::PlanCache::new();
        let mut plan_for = cached_plan_provider(&plans, "m", &kernel);
        let d = sweep(&kernel, &thread_ladder(3), &TrialBudget::zero(), &mut plan_for);
        assert!(!d.measured && d.sweep.is_empty() && d.trials.is_empty());
        // n=100 < the fork-join threshold → sequential at one thread.
        assert_eq!(d.kind, EngineKind::Sequential);
        assert_eq!(d.nthreads, 1);
        assert_eq!(d.max_threads, 3);
        // Zero budget measures no block axis; the width is the
        // heuristic's answer (small matrix → no blocking).
        assert!(d.block_rates.is_empty());
        assert_eq!(d.block_k, 1);
    }

    #[test]
    fn resolve_swept_upgrades_single_p_entries_and_then_hits() {
        let (kernel, plan) = kernel_and_plan(130, 8, 2);
        let cache = DecisionCache::in_memory();
        // A plain single-p tune at the same thread budget…
        let (d0, hit0) = resolve(&kernel, &plan, &TrialBudget::smoke(), &cache, ReorderPolicy::Never);
        assert!(!hit0 && d0.measured && d0.sweep.is_empty());
        let plans = crate::plan::PlanCache::new();
        let mut plan_for = cached_plan_provider(&plans, "m", &kernel);
        // …does not satisfy a sweeping caller with a measuring budget:
        // the entry is upgraded in place with the full surface.
        let ladder = thread_ladder(2);
        let (d1, hit1) = resolve_swept(
            &kernel,
            &ladder,
            &TrialBudget::smoke(),
            &cache,
            &mut plan_for,
            ReorderPolicy::Never,
        );
        assert!(!hit1 && d1.measured && !d1.sweep.is_empty());
        assert_eq!(cache.len(), 1, "the swept decision replaces the single-p entry");
        // From now on, sweeping callers hit.
        let (d2, hit2) = resolve_swept(
            &kernel,
            &ladder,
            &TrialBudget::smoke(),
            &cache,
            &mut plan_for,
            ReorderPolicy::Never,
        );
        assert!(hit2);
        assert_eq!(d2.kind, d1.kind);
        assert_eq!(d2.nthreads, d1.nthreads);
        // A zero-budget sweeping caller is happy with whatever is there.
        let (_, hit3) = resolve_swept(
            &kernel,
            &ladder,
            &TrialBudget::zero(),
            &cache,
            &mut plan_for,
            ReorderPolicy::Never,
        );
        assert!(hit3);
    }

    #[test]
    fn resolve_serves_single_p_view_of_swept_entries() {
        // A swept entry whose global winner sits at p=1 must not leak
        // that thread count to a non-sweeping caller who asked for p=2:
        // the caller gets the p=2 rung's winner (RoutePolicy::threads
        // keeps its meaning unless sweep_threads opted in).
        let (kernel, plan) = kernel_and_plan(140, 10, 2);
        let cache = DecisionCache::in_memory();
        let fp = fingerprint(kernel.as_ref());
        let seq = TrialResult {
            kind: EngineKind::Sequential,
            reordered: false,
            seconds_per_product: 1e-4,
            mad_s: 0.0,
            mflops: 120.0,
        };
        let rung2 = vec![
            TrialResult {
                kind: EngineKind::Atomic,
                reordered: false,
                seconds_per_product: 2e-4,
                mad_s: 0.0,
                mflops: 40.0,
            },
            TrialResult {
                kind: EngineKind::Colorful,
                reordered: false,
                seconds_per_product: 1e-4,
                mad_s: 0.0,
                mflops: 80.0,
            },
        ];
        cache.put(Decision {
            kind: EngineKind::Sequential,
            reorder: false,
            mflops: 120.0,
            measured: true,
            provenance: Provenance::Measured,
            served_mflops: 0.0,
            tuned_s: 0.01,
            fingerprint: fp,
            nthreads: 1,
            max_threads: 2,
            features: Features::extract(kernel.as_ref(), &plan),
            trials: vec![seq.clone()],
            sweep: vec![
                SweepPoint { nthreads: 1, trials: vec![seq] },
                SweepPoint { nthreads: 2, trials: rung2 },
            ],
            block_k: 1,
            block_rates: Vec::new(),
        });
        let (d, hit) =
            resolve(&kernel, &plan, &TrialBudget::smoke(), &cache, ReorderPolicy::Never);
        assert!(hit, "the swept entry satisfies the single-p caller");
        assert_eq!(d.nthreads, 2, "the view answers at the caller's thread count");
        assert_eq!(d.kind, EngineKind::Colorful, "…with that rung's winner");
        assert_eq!(d.mflops, 80.0);
        assert_eq!(d.trials.len(), 2, "…and that rung's trials");
    }

    #[test]
    fn sweep_measures_sequential_once() {
        // The sequential sweep ignores p — its trial is taken at the
        // first rung and reused, so every rung still reports it but the
        // identical measurement is not repeated.
        let (kernel, _) = kernel_and_plan(150, 11, 2);
        let plans = crate::plan::PlanCache::new();
        let mut plan_for = cached_plan_provider(&plans, "m", &kernel);
        let d = sweep(&kernel, &thread_ladder(2), &TrialBudget::smoke(), &mut plan_for);
        let seq1 = d.sweep[0].trials.iter().find(|t| t.kind == EngineKind::Sequential).unwrap();
        let seq2 = d.sweep[1].trials.iter().find(|t| t.kind == EngineKind::Sequential).unwrap();
        assert_eq!(seq1.seconds_per_product, seq2.seconds_per_product);
        assert_eq!(seq1.mflops, seq2.mflops);
    }

    #[test]
    fn duplicated_candidates_measure_consistently() {
        // Regression guard for the cold-start bias: with one untimed
        // warm-up product per engine, a duplicated candidate cannot be
        // penalized for running first (pool spin-up, cold caches) even
        // under a one-run budget where the median cannot absorb it.
        let (kernel, plan) = kernel_and_plan(3000, 9, 2);
        let kind = EngineKind::LocalBuffers(AccumMethod::Effective);
        let trials = measure_candidates(
            &kernel,
            &plan,
            &TrialBudget { runs: 1, products: 4 },
            Features::extract(kernel.as_ref(), &plan).work_flops,
            &[kind, kind, kind],
        );
        assert_eq!(trials.len(), 3);
        let rates: Vec<f64> = trials.iter().map(|t| t.mflops).collect();
        let lo = rates.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = rates.iter().cloned().fold(0.0, f64::max);
        assert!(lo > 0.0);
        assert!(
            hi / lo < 5.0,
            "duplicated candidates must measure consistently, got {rates:?}"
        );
    }

    #[test]
    fn zero_budget_answers_from_cost_model() {
        let (kernel, plan) = kernel_and_plan(100, 2, 3);
        let d = tune(&kernel, &plan, &TrialBudget::zero());
        assert!(!d.measured);
        assert_eq!(d.provenance, Provenance::Heuristic, "no model supplied");
        assert!(d.trials.is_empty());
        assert_ne!(d.kind, EngineKind::Auto);
        // n=100 < the fork-join threshold → sequential.
        assert_eq!(d.kind, EngineKind::Sequential);
    }

    /// A model trained on a corpus that always crowned one engine is a
    /// constant predictor — unmistakable next to the heuristic when the
    /// planted pick is something `cost_model` never chooses.
    fn constant_model(features: &Features, kind: EngineKind, rungs: &[(usize, f64)]) -> CostModel {
        let rows: Vec<model::CorpusRow> = (0..4u64)
            .map(|i| model::CorpusRow {
                fingerprint: i,
                max_threads: features.nthreads,
                features: features.clone(),
                kind,
                reordered: false,
                nthreads: features.nthreads,
                rung_rates: rungs.to_vec(),
                block_rates: Vec::new(),
            })
            .collect();
        CostModel::train(&rows).expect("non-empty corpus trains")
    }

    #[test]
    fn zero_budget_resolve_consults_the_model_before_the_heuristic() {
        // ISSUE 5 acceptance: with an empty decision cache and
        // TrialBudget::zero(), resolve answers from the trained model
        // when one is supplied, and from the heuristic only when not.
        let (kernel, plan) = kernel_and_plan(150, 31, 2);
        let features = Features::extract(kernel.as_ref(), &plan);
        let m = constant_model(&features, EngineKind::Atomic, &[(2, 500.0)]);
        let cache = DecisionCache::in_memory();
        let (d, hit) = resolve_with_model(
            &kernel,
            &plan,
            &TrialBudget::zero(),
            &cache,
            ReorderPolicy::Never,
            Some(&m),
        );
        assert!(!hit && !d.measured);
        assert_eq!(d.provenance, Provenance::Model);
        assert_eq!(d.kind, EngineKind::Atomic, "the planted model pick, not the heuristic's");
        // Without a model the same call answers from the heuristic.
        let cache2 = DecisionCache::in_memory();
        let (d2, _) = resolve_with_model(
            &kernel,
            &plan,
            &TrialBudget::zero(),
            &cache2,
            ReorderPolicy::Never,
            None,
        );
        assert_eq!(d2.provenance, Provenance::Heuristic);
        assert_eq!(d2.kind, cost_model(&d2.features));
        // A measuring budget ignores the model and runs real trials.
        let (d3, hit3) = resolve_with_model(
            &kernel,
            &plan,
            &TrialBudget::smoke(),
            &cache2,
            ReorderPolicy::Never,
            Some(&m),
        );
        assert!(!hit3 && d3.measured);
        assert_eq!(d3.provenance, Provenance::Measured);
        // The model decision was cached: later zero-budget callers hit.
        let (d4, hit4) = resolve_with_model(
            &kernel,
            &plan,
            &TrialBudget::zero(),
            &cache,
            ReorderPolicy::Never,
            None,
        );
        assert!(hit4, "model placeholders are cached like heuristic ones");
        assert_eq!(d4.provenance, Provenance::Model);
    }

    #[test]
    fn a_later_supplied_model_upgrades_heuristic_placeholders() {
        // A zero-budget resolve without a model writes a heuristic
        // placeholder. Training a model afterwards must not leave it
        // silently dead: the next model-armed zero-budget caller
        // re-predicts and upgrades the entry in place.
        let (kernel, plan) = kernel_and_plan(150, 33, 2);
        let cache = DecisionCache::in_memory();
        let (d0, hit0) =
            resolve(&kernel, &plan, &TrialBudget::zero(), &cache, ReorderPolicy::Never);
        assert!(!hit0);
        assert_eq!(d0.provenance, Provenance::Heuristic);
        let features = Features::extract(kernel.as_ref(), &plan);
        let m = constant_model(&features, EngineKind::Atomic, &[(2, 500.0)]);
        let (d1, hit1) = resolve_with_model(
            &kernel,
            &plan,
            &TrialBudget::zero(),
            &cache,
            ReorderPolicy::Never,
            Some(&m),
        );
        assert!(!hit1, "a heuristic placeholder must not satisfy a model-armed caller");
        assert_eq!(d1.provenance, Provenance::Model);
        assert_eq!(d1.kind, EngineKind::Atomic);
        // The upgraded (model) placeholder now satisfies the same caller
        // — no churn on every resolve.
        let (d2, hit2) = resolve_with_model(
            &kernel,
            &plan,
            &TrialBudget::zero(),
            &cache,
            ReorderPolicy::Never,
            Some(&m),
        );
        assert!(hit2);
        assert_eq!(d2.provenance, Provenance::Model);
        assert_eq!(cache.len(), 1, "upgrade in place, not a second entry");
        // A model that cannot answer under the caller's policy (trained
        // only on reordered winners, asked under Never) must leave the
        // placeholder alone instead of re-missing on every resolve.
        let reordered_rows: Vec<model::CorpusRow> = (0..4u64)
            .map(|i| model::CorpusRow {
                fingerprint: i,
                max_threads: 2,
                features: features.clone(),
                kind: EngineKind::Colorful,
                reordered: true,
                nthreads: 2,
                rung_rates: vec![(2, 500.0)],
                block_rates: Vec::new(),
            })
            .collect();
        let blind = CostModel::train(&reordered_rows).unwrap();
        let cache3 = DecisionCache::in_memory();
        let _ = resolve(&kernel, &plan, &TrialBudget::zero(), &cache3, ReorderPolicy::Never);
        let (d3, hit3) = resolve_with_model(
            &kernel,
            &plan,
            &TrialBudget::zero(),
            &cache3,
            ReorderPolicy::Never,
            Some(&blind),
        );
        assert!(hit3, "a model that declines under Never must not invalidate the entry");
        assert_eq!(d3.provenance, Provenance::Heuristic);
    }

    #[test]
    fn always_caller_re_answers_a_plain_placeholder() {
        // Shared cache, no model: a Never caller's plain zero-budget
        // placeholder must not pin a later Always caller to unreordered
        // execution (workers serve the resolved decision's flag).
        let (kernel, plan) = kernel_and_plan(150, 36, 2);
        let cache = DecisionCache::in_memory();
        let (d0, _) =
            resolve(&kernel, &plan, &TrialBudget::zero(), &cache, ReorderPolicy::Never);
        assert!(!d0.reorder);
        let (d1, hit1) =
            resolve(&kernel, &plan, &TrialBudget::zero(), &cache, ReorderPolicy::Always);
        assert!(!hit1, "a plain placeholder must not satisfy an Always caller");
        assert!(d1.reorder);
        // And back: cross-policy zero-budget callers sharing one cache
        // alternate at registration time, each served its own ordering.
        let (d2, hit2) =
            resolve(&kernel, &plan, &TrialBudget::zero(), &cache, ReorderPolicy::Always);
        assert!(hit2 && d2.reorder, "same-policy callers hit — no churn");
        let (d3, hit3) =
            resolve(&kernel, &plan, &TrialBudget::zero(), &cache, ReorderPolicy::Never);
        assert!(!hit3 && !d3.reorder);
    }

    #[test]
    fn retrained_thread_pick_re_answers_swept_model_placeholders() {
        let (kernel, _) = kernel_and_plan(150, 35, 4);
        let plans = crate::plan::PlanCache::new();
        let mut plan_for = cached_plan_provider(&plans, "m", &kernel);
        let plan = plan_for(4);
        let features = Features::extract(kernel.as_ref(), &plan);
        let cache = DecisionCache::in_memory();
        let ladder = thread_ladder(4);
        let fast_high =
            constant_model(&features, EngineKind::Colorful, &[(2, 100.0), (4, 900.0)]);
        let (d1, _) = resolve_swept_with_model(
            &kernel,
            &ladder,
            &TrialBudget::zero(),
            &cache,
            &mut plan_for,
            ReorderPolicy::Never,
            Some(&fast_high),
        );
        assert_eq!((d1.kind, d1.nthreads), (EngineKind::Colorful, 4));
        // Retrained rung regressors now peak at p = 2: same engine
        // class, moved thread pick — the swept placeholder must be
        // re-answered, not served stale.
        let fast_low =
            constant_model(&features, EngineKind::Colorful, &[(2, 900.0), (4, 100.0)]);
        let (d2, hit2) = resolve_swept_with_model(
            &kernel,
            &ladder,
            &TrialBudget::zero(),
            &cache,
            &mut plan_for,
            ReorderPolicy::Never,
            Some(&fast_low),
        );
        assert!(!hit2, "a moved thread pick must re-answer the placeholder");
        assert_eq!((d2.kind, d2.nthreads), (EngineKind::Colorful, 2));
        // Agreement hits — no churn.
        let (d3, hit3) = resolve_swept_with_model(
            &kernel,
            &ladder,
            &TrialBudget::zero(),
            &cache,
            &mut plan_for,
            ReorderPolicy::Never,
            Some(&fast_low),
        );
        assert!(hit3);
        assert_eq!(d3.nthreads, 2);
    }

    #[test]
    fn never_caller_does_not_inherit_a_reordered_placeholder_kind() {
        // A zero-budget Measure resolution can cache an unmeasured
        // *reordered* model pick. A later Never caller must not be
        // served that engine with the flag stripped — the model's
        // plain-class pick can be a different engine — it re-answers
        // plain and upgrades the placeholder.
        let (kernel, plan) = kernel_and_plan(150, 34, 2);
        let features = Features::extract(kernel.as_ref(), &plan);
        // Two classes separated on scatter_ratio: this matrix's own
        // features ⇒ reordered/colorful; far-off scatter ⇒ plain
        // interval. Under Never only the plain class is eligible.
        let mut far = features.clone();
        far.scatter_ratio = 0.01;
        let rows: Vec<model::CorpusRow> = (0..8u64)
            .map(|i| {
                let near = i % 2 == 0;
                model::CorpusRow {
                    fingerprint: i,
                    max_threads: 2,
                    features: if near { features.clone() } else { far.clone() },
                    kind: if near {
                        EngineKind::Colorful
                    } else {
                        EngineKind::LocalBuffers(AccumMethod::Interval)
                    },
                    reordered: near,
                    nthreads: 2,
                    rung_rates: vec![(2, 500.0)],
                    block_rates: Vec::new(),
                }
            })
            .collect();
        let m = CostModel::train(&rows).unwrap();
        let cache = DecisionCache::in_memory();
        let (d1, _) = resolve_with_model(
            &kernel,
            &plan,
            &TrialBudget::zero(),
            &cache,
            ReorderPolicy::Measure,
            Some(&m),
        );
        assert!(!d1.measured && d1.reorder, "Measure caches the reordered model pick");
        assert_eq!(d1.kind, EngineKind::Colorful);
        let (d2, hit2) = resolve_with_model(
            &kernel,
            &plan,
            &TrialBudget::zero(),
            &cache,
            ReorderPolicy::Never,
            Some(&m),
        );
        assert!(!hit2, "a reordered placeholder must not satisfy a Never caller");
        assert!(!d2.reorder);
        assert_eq!(d2.kind, EngineKind::LocalBuffers(AccumMethod::Interval));
        // The plain placeholder now satisfies Never callers — no churn.
        let (d3, hit3) = resolve_with_model(
            &kernel,
            &plan,
            &TrialBudget::zero(),
            &cache,
            ReorderPolicy::Never,
            Some(&m),
        );
        assert!(hit3);
        assert_eq!(d3.kind, EngineKind::LocalBuffers(AccumMethod::Interval));
        // …and the mirror direction: a Measure caller whose model
        // disagrees with the plain placeholder re-answers too, instead
        // of being pinned to the Never caller's engine.
        let (d4, hit4) = resolve_with_model(
            &kernel,
            &plan,
            &TrialBudget::zero(),
            &cache,
            ReorderPolicy::Measure,
            Some(&m),
        );
        assert!(!hit4, "a disagreeing Measure caller must re-answer");
        assert!(d4.reorder);
        assert_eq!(d4.kind, EngineKind::Colorful);
    }

    #[test]
    fn zero_budget_sweep_takes_model_engine_and_thread_pick() {
        let (kernel, _) = kernel_and_plan(150, 32, 2);
        let plans = crate::plan::PlanCache::new();
        let mut plan_for = cached_plan_provider(&plans, "m", &kernel);
        let plan = plan_for(2);
        let features = Features::extract(kernel.as_ref(), &plan);
        // Rate surface planted to peak at p = 2.
        let m = constant_model(&features, EngineKind::Colorful, &[(1, 100.0), (2, 900.0)]);
        let cache = DecisionCache::in_memory();
        let (d, hit) = resolve_swept_with_model(
            &kernel,
            &thread_ladder(2),
            &TrialBudget::zero(),
            &cache,
            &mut plan_for,
            ReorderPolicy::Never,
            Some(&m),
        );
        assert!(!hit && !d.measured);
        assert_eq!(d.provenance, Provenance::Model);
        assert_eq!(d.kind, EngineKind::Colorful);
        assert_eq!(d.nthreads, 2, "thread pick follows the trained rate surface");
        assert_eq!(d.max_threads, 2);
    }

    #[test]
    fn cost_model_prefers_effective_for_scatter_free() {
        let mut rng = Rng::new(3);
        let coo = Coo::random_structurally_symmetric(5000, 3, false, &mut rng);
        let csr = Csr::from_coo(&coo);
        let plan = PlanBuilder::all(4).build(&csr);
        let f = Features::extract(&csr, &plan);
        assert_eq!(cost_model(&f), EngineKind::LocalBuffers(AccumMethod::Effective));
    }

    #[test]
    fn resolve_runs_once_then_hits_the_cache() {
        let (kernel, plan) = kernel_and_plan(120, 4, 2);
        let cache = DecisionCache::in_memory();
        let (d1, hit1) =
            resolve(&kernel, &plan, &TrialBudget::smoke(), &cache, ReorderPolicy::Never);
        assert!(!hit1);
        let (d2, hit2) =
            resolve(&kernel, &plan, &TrialBudget::smoke(), &cache, ReorderPolicy::Never);
        assert!(hit2, "second resolve of the same structure must not re-tune");
        assert_eq!(d1.kind, d2.kind);
        // A different thread count is a different decision.
        let plan3 = Arc::new(PlanBuilder::all(3).build(kernel.as_ref()));
        let (_, hit3) =
            resolve(&kernel, &plan3, &TrialBudget::smoke(), &cache, ReorderPolicy::Never);
        assert!(!hit3);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn reordered_candidates_double_the_set() {
        let plain = candidates_with_reorder(4, false);
        assert_eq!(plain.len(), candidates(4).len());
        assert!(plain.iter().all(|c| !c.reordered));
        let both = candidates_with_reorder(4, true);
        assert_eq!(both.len(), 2 * candidates(4).len());
        assert_eq!(both.iter().filter(|c| c.reordered).count(), candidates(4).len());
    }

    #[test]
    fn tune_reordered_measures_both_orderings() {
        // A shuffled band: RCM has real room, so the reordered trials
        // are genuinely different engines. The winner is whichever
        // measured faster — asserted structurally, not by timing.
        let mut rng = Rng::new(21);
        let band = Csrc::from_coo(&Coo::banded(400, 2, false, &mut rng)).unwrap();
        let shuffle =
            crate::reorder::Permutation::from_new_to_old(rng.permutation(400)).unwrap();
        let shuffled = band.permuted(&shuffle);
        let kernel: Arc<dyn SpmvKernel> = Arc::new(shuffled);
        let plan = Arc::new(PlanBuilder::all(2).build(kernel.as_ref()));
        let d = tune_reordered(&kernel, &plan, &TrialBudget::smoke(), ReorderPolicy::Measure);
        assert!(d.measured);
        assert_eq!(d.trials.len(), 2 * candidates(2).len());
        assert!(d.trials.iter().any(|t| t.reordered));
        assert!(d.trials.iter().any(|t| !t.reordered));
        // The decision's reorder flag is the winning trial's.
        let best = d
            .trials
            .iter()
            .max_by(|a, b| a.mflops.partial_cmp(&b.mflops).unwrap())
            .unwrap();
        assert_eq!(d.reorder, best.reordered);
        assert_eq!(d.kind, best.kind);
        // Always restricts the search to the reordered half.
        let da = tune_reordered(&kernel, &plan, &TrialBudget::smoke(), ReorderPolicy::Always);
        assert!(da.reorder && da.trials.iter().all(|t| t.reordered));
        // Never stays plain even on the same matrix.
        let dn = tune_reordered(&kernel, &plan, &TrialBudget::smoke(), ReorderPolicy::Never);
        assert!(!dn.reorder && dn.trials.iter().all(|t| !t.reordered));
    }

    #[test]
    fn sweep_reordered_covers_every_rung_with_both_orderings() {
        let mut rng = Rng::new(22);
        let band = Csrc::from_coo(&Coo::banded(300, 2, false, &mut rng)).unwrap();
        let shuffle =
            crate::reorder::Permutation::from_new_to_old(rng.permutation(300)).unwrap();
        let kernel: Arc<dyn SpmvKernel> = Arc::new(band.permuted(&shuffle));
        let plans = crate::plan::PlanCache::new();
        let mut plan_for = cached_plan_provider(&plans, "m", &kernel);
        let d = sweep_reordered(
            &kernel,
            &thread_ladder(2),
            &TrialBudget::smoke(),
            &mut plan_for,
            ReorderPolicy::Measure,
        );
        assert!(d.measured);
        assert_eq!(d.sweep.len(), 2);
        for pt in &d.sweep {
            assert_eq!(pt.trials.len(), 2 * candidates(pt.nthreads).len());
            assert!(pt.trials.iter().any(|t| t.reordered));
            assert!(pt.trials.iter().any(|t| !t.reordered));
        }
        // The reordered sequential trial, like the plain one, is
        // measured once and shared across rungs.
        let rs1 = d.sweep[0]
            .trials
            .iter()
            .find(|t| t.kind == EngineKind::Sequential && t.reordered)
            .unwrap();
        let rs2 = d.sweep[1]
            .trials
            .iter()
            .find(|t| t.kind == EngineKind::Sequential && t.reordered)
            .unwrap();
        assert_eq!(rs1.seconds_per_product, rs2.seconds_per_product);
    }

    #[test]
    fn reorder_context_skips_unimprovable_orderings() {
        // A matrix RCM cannot improve (diagonal: bandwidth already 0)
        // yields no reorder context — the gather cost would buy nothing.
        let mut coo = Coo::new(6, 6);
        for i in 0..6 {
            coo.push(i, i, 2.0);
        }
        coo.compact();
        let kernel: Arc<dyn SpmvKernel> = Arc::new(Csrc::from_coo(&coo).unwrap());
        let plan = Arc::new(PlanBuilder::all(2).build(kernel.as_ref()));
        assert!(reorder_context(&kernel, &plan).is_none());
        // Tuning with Always on such a kernel falls back to plain trials.
        let d = tune_reordered(&kernel, &plan, &TrialBudget::smoke(), ReorderPolicy::Always);
        assert!(!d.reorder);
        assert!(d.trials.iter().all(|t| !t.reordered));
    }

    #[test]
    fn never_policy_demotes_cached_reordered_winners() {
        // A cache written under `Measure` records a reordered winner; a
        // later `Never` caller must get a plain decision (best plain
        // trial), not a silently re-enabled permute/un-permute path.
        let (kernel, plan) = kernel_and_plan(120, 12, 2);
        let cache = DecisionCache::in_memory();
        let fp = fingerprint(kernel.as_ref());
        let trials = vec![
            TrialResult {
                kind: EngineKind::Colorful,
                reordered: false,
                seconds_per_product: 2e-4,
                mad_s: 0.0,
                mflops: 50.0,
            },
            TrialResult {
                kind: EngineKind::LocalBuffers(AccumMethod::Effective),
                reordered: true,
                seconds_per_product: 1e-4,
                mad_s: 0.0,
                mflops: 100.0,
            },
        ];
        cache.put(Decision {
            kind: EngineKind::LocalBuffers(AccumMethod::Effective),
            reorder: true,
            mflops: 100.0,
            measured: true,
            provenance: Provenance::Measured,
            served_mflops: 0.0,
            tuned_s: 0.01,
            fingerprint: fp,
            nthreads: 2,
            max_threads: 2,
            features: Features::extract(kernel.as_ref(), &plan),
            trials,
            sweep: Vec::new(),
            block_k: 1,
            block_rates: Vec::new(),
        });
        let (d, hit) =
            resolve(&kernel, &plan, &TrialBudget::smoke(), &cache, ReorderPolicy::Never);
        assert!(hit, "the measured entry still satisfies the caller");
        assert!(!d.reorder, "Never must clear the reorder flag");
        assert_eq!(d.kind, EngineKind::Colorful, "…and demote to the best plain trial");
        assert_eq!(d.mflops, 50.0);
        // A Measure caller keeps the recorded reordered winner.
        let (d2, hit2) =
            resolve(&kernel, &plan, &TrialBudget::smoke(), &cache, ReorderPolicy::Measure);
        assert!(hit2 && d2.reorder);
        assert_eq!(d2.kind, EngineKind::LocalBuffers(AccumMethod::Effective));
    }

    #[test]
    fn single_thread_candidates_are_sequential_only() {
        assert_eq!(candidates(1), vec![EngineKind::Sequential]);
        assert!(candidates(4).contains(&EngineKind::Colorful));
        assert!(candidates(4).contains(&EngineKind::Atomic));
        assert!(!candidates(4).contains(&EngineKind::Auto));
        // One thread needs no analysis pieces; two need everything.
        assert_eq!(required_pieces(1), PlanPieces::default());
        assert_eq!(required_pieces(2), PlanPieces::all());
    }

    #[test]
    fn measured_budget_upgrades_a_cached_cost_model_decision() {
        let (kernel, plan) = kernel_and_plan(130, 5, 2);
        let cache = DecisionCache::in_memory();
        let (d0, hit0) =
            resolve(&kernel, &plan, &TrialBudget::zero(), &cache, ReorderPolicy::Never);
        assert!(!hit0 && !d0.measured);
        // Zero-budget callers keep hitting the heuristic entry...
        let (_, hit1) =
            resolve(&kernel, &plan, &TrialBudget::zero(), &cache, ReorderPolicy::Never);
        assert!(hit1);
        // ...but a measuring budget re-tunes instead of freezing it.
        let (d2, hit2) =
            resolve(&kernel, &plan, &TrialBudget::smoke(), &cache, ReorderPolicy::Never);
        assert!(!hit2 && d2.measured);
        // And the upgraded (measured) entry now satisfies everyone.
        let (d3, hit3) =
            resolve(&kernel, &plan, &TrialBudget::smoke(), &cache, ReorderPolicy::Never);
        assert!(hit3 && d3.measured);
    }
}
