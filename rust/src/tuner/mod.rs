//! Autotuner — measurement-driven per-matrix engine selection.
//!
//! The paper's headline result is that **no single strategy wins
//! everywhere**: local buffers beat coloring for most matrices, but the
//! best accumulation method varies with structure (§4). This subsystem
//! makes that observation operational instead of leaving the choice to
//! the caller:
//!
//! 1. [`Features::extract`] reads the structural signals the decision
//!    depends on (order, work, scatter ratio, write bandwidth, color and
//!    interval counts, partition balance) from a [`SpmvKernel`] and its
//!    full [`SpmvPlan`];
//! 2. [`tune`] runs short measured trials of every candidate engine —
//!    the paper's median-of-runs protocol
//!    ([`crate::metrics::median_and_spread_of_runs`]) under a
//!    configurable [`TrialBudget`] — and emits a [`Decision`];
//! 3. [`sweep`] adds the second dimension the paper's §4 scalability
//!    curves demand: the same trials repeated across a thread-count
//!    ladder ([`thread_ladder`]), because several matrices peak *below*
//!    the machine's core count — the winning `(engine, nthreads)` pair
//!    plus the full sweep surface land in the [`Decision`];
//! 4. [`tune_reordered`] / [`sweep_reordered`] add the *reorder* axis
//!    ([`candidates_with_reorder`]): under
//!    [`crate::reorder::ReorderPolicy::Measure`] every candidate also
//!    runs through the RCM ordering (timed behind a
//!    [`ReorderedEngine`], so the per-product permute/un-permute
//!    gathers count), and [`Decision::reorder`] records the winner's
//!    ordering;
//! 5. a zero budget skips the trials and falls back to [`cost_model`],
//!    a paper-derived heuristic over the same features;
//! 6. [`resolve`] / [`resolve_swept`] front the whole thing with a
//!    persistent [`DecisionCache`] keyed by (structure [`fingerprint`] ×
//!    thread budget), so a restarted service never re-tunes a known
//!    matrix.
//!
//! [`crate::parallel::EngineKind::Auto`] is the routing-level entry
//! point: the coordinator resolves it here at registration time and the
//! workers only ever see concrete engines.

pub mod cache;
pub mod features;

pub use cache::{decision_json, DecisionCache};
pub use features::{fingerprint, Features};

use crate::metrics;
use crate::parallel::{build_engine, AccumMethod, EngineKind, ParallelSpmv};
use crate::plan::{PlanBuilder, PlanCache, PlanPieces, SpmvPlan};
use crate::reorder::{self, Permutation, ReorderPolicy, ReorderedEngine};
use crate::sparse::SpmvKernel;
use std::sync::Arc;
use std::time::Instant;

/// How much measuring a tuning run may do: `runs` timed repetitions of
/// `products` back-to-back products per candidate engine (the paper's §4
/// protocol, scaled down). A zero budget means "no trials": the decision
/// comes from [`cost_model`] alone.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TrialBudget {
    pub runs: usize,
    pub products: usize,
}

impl Default for TrialBudget {
    fn default() -> Self {
        TrialBudget { runs: 3, products: 8 }
    }
}

impl TrialBudget {
    /// No measuring at all — [`tune`] answers from the cost model.
    pub fn zero() -> TrialBudget {
        TrialBudget { runs: 0, products: 0 }
    }

    /// Cheapest measured budget (CI smoke runs).
    pub fn smoke() -> TrialBudget {
        TrialBudget { runs: 1, products: 2 }
    }

    pub fn is_zero(&self) -> bool {
        self.runs == 0 || self.products == 0
    }
}

/// One candidate's measurement.
#[derive(Clone, Debug)]
pub struct TrialResult {
    pub kind: EngineKind,
    /// True when this trial ran through the RCM ordering (engine over
    /// the permuted kernel behind a [`ReorderedEngine`] wrapper, so the
    /// per-product permute/un-permute gathers are inside the timing).
    pub reordered: bool,
    /// Median seconds per product across the budgeted runs.
    pub seconds_per_product: f64,
    /// MAD across runs — how noisy the median is.
    pub mad_s: f64,
    /// Rate normalized by the kernel's work units ([`Features::work_flops`]).
    pub mflops: f64,
}

impl TrialResult {
    /// Display label: the engine kind, `reordered/`-prefixed when the
    /// trial ran through the RCM ordering.
    pub fn label(&self) -> String {
        if self.reordered {
            format!("reordered/{}", self.kind.label())
        } else {
            self.kind.label()
        }
    }
}

/// One rung of the thread-count ladder in a swept decision: every
/// candidate engine's measurement at `nthreads`.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub nthreads: usize,
    pub trials: Vec<TrialResult>,
}

impl SweepPoint {
    /// The fastest candidate at this thread count.
    pub fn best(&self) -> Option<&TrialResult> {
        self.trials
            .iter()
            .max_by(|a, b| a.mflops.partial_cmp(&b.mflops).expect("rates are finite"))
    }
}

/// The tuner's verdict for one matrix × thread budget.
#[derive(Clone, Debug)]
pub struct Decision {
    /// The winning concrete engine (never [`EngineKind::Auto`]).
    pub kind: EngineKind,
    /// True when the winner ran through the RCM ordering — the caller
    /// must execute via the permuted matrix with permute/un-permute per
    /// product ([`ReorderedEngine`] / [`crate::reorder::ReorderedLinOp`]).
    pub reorder: bool,
    /// The winner's measured rate (0 when `measured` is false).
    pub mflops: f64,
    /// False when the decision came from [`cost_model`] without trials.
    pub measured: bool,
    /// Wall-clock seconds the tuning run itself cost.
    pub tuned_s: f64,
    /// Structure fingerprint — the cache key, with `max_threads`.
    pub fingerprint: u64,
    /// Thread count the winning engine should run at.
    pub nthreads: usize,
    /// Thread budget the decision was tuned under — the second half of
    /// the cache key. A swept decision may pick `nthreads < max_threads`
    /// (the §4 curves: "more threads" is not monotone once memory
    /// bandwidth saturates); single-p decisions have the two equal.
    pub max_threads: usize,
    pub features: Features,
    /// The winning thread count's trials (every candidate at that p).
    pub trials: Vec<TrialResult>,
    /// Full (engine × nthreads) sweep surface; empty for single-p
    /// decisions and for entries loaded from a v1 cache file.
    pub sweep: Vec<SweepPoint>,
}

impl Decision {
    /// Display label of the winner: the engine kind, `reordered/`-
    /// prefixed when the decision executes through the RCM ordering —
    /// the single source for every log/stat that prints a decision.
    pub fn label(&self) -> String {
        if self.reorder {
            format!("reordered/{}", self.kind.label())
        } else {
            self.kind.label()
        }
    }
}

/// The candidate set for a thread count: every concrete engine that can
/// possibly win, including the sequential baseline (small matrices do not
/// amortize fork-join — the paper's §4.2 one-thread shortcut) and the
/// atomics baseline the paper dismisses (measurement, not folklore,
/// decides).
pub fn candidates(nthreads: usize) -> Vec<EngineKind> {
    let mut v = vec![EngineKind::Sequential];
    if nthreads > 1 {
        v.extend(EngineKind::all_local_buffers());
        v.push(EngineKind::Colorful);
        v.push(EngineKind::Atomic);
    }
    v
}

/// One (engine × ordering) candidate of the two-axis search: the
/// engine kinds of [`candidates`] crossed with whether the trial runs
/// through the RCM ordering.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Candidate {
    pub kind: EngineKind,
    pub reordered: bool,
}

/// [`candidates`] with the reorder axis: every kind plain, plus —
/// when `reorder` — every kind again through the RCM ordering, so the
/// tuner measures reorder-on vs reorder-off per matrix instead of
/// assuming either. (Reordered sequential is a real candidate: a
/// bandwidth win that needs no threads at all.)
pub fn candidates_with_reorder(nthreads: usize, reorder: bool) -> Vec<Candidate> {
    let mut v: Vec<Candidate> = candidates(nthreads)
        .into_iter()
        .map(|kind| Candidate { kind, reordered: false })
        .collect();
    if reorder {
        v.extend(
            candidates(nthreads).into_iter().map(|kind| Candidate { kind, reordered: true }),
        );
    }
    v
}

/// Plan pieces the tuner needs at a thread count — the union over
/// [`candidates`]. Everything at p ≥ 2; only the base partition at
/// p = 1, where the sole candidate is the sequential sweep and paying
/// for conflict coloring would be pure waste.
pub fn required_pieces(nthreads: usize) -> PlanPieces {
    let mut need = PlanPieces::default();
    for kind in candidates(nthreads) {
        need = need.union(PlanPieces::for_kind(kind));
    }
    need
}

/// A [`sweep`] plan provider backed by a shared [`PlanCache`]: one
/// analysis per (key × thread count), each plan built with exactly
/// [`required_pieces`]`(p)` — the contract [`sweep`] asserts. Every
/// sweeping call site (service registration, background re-tune, CLI,
/// figure harness, benches) goes through this so the contract cannot be
/// broken by a hand-rolled closure.
pub fn cached_plan_provider<'a>(
    plans: &'a PlanCache,
    key: &'a str,
    kernel: &'a Arc<dyn SpmvKernel>,
) -> impl FnMut(usize) -> Arc<SpmvPlan> + 'a {
    move |p: usize| {
        let builder = PlanBuilder::new(p).with_pieces(required_pieces(p));
        plans.get_or_build(key, kernel.as_ref(), builder)
    }
}

/// The thread-count ladder a [`sweep`] trials: 1, 2, 4, … doubling up to
/// and always including `max` (the paper's §4 scalability axis, scaled
/// to the caller's thread budget). `max == 0` is treated as 1.
pub fn thread_ladder(max: usize) -> Vec<usize> {
    let max = max.max(1);
    let mut v = Vec::new();
    let mut p = 1;
    while p < max {
        v.push(p);
        p *= 2;
    }
    v.push(max);
    v
}

/// Paper-derived heuristic over structural features — the zero-budget
/// fallback, also used by workers racing a registration-time tuning run.
///
/// * Small orders don't amortize fork-join: sequential (§4.2).
/// * A scatter-free kernel (CSR-like) has block-exact effective ranges,
///   so `local-buffers/effective` degenerates to the ideal row split.
/// * Almost-conflict-free patterns (≤ 2 colors) suit the colorful
///   schedule: barely any serialization between classes (§3.2).
/// * Otherwise local buffers win "for most matrices" (§4.3); a fine
///   interval decomposition indicates scattered write ranges where the
///   interval accumulation amortizes best, else effective accumulation.
pub fn cost_model(f: &Features) -> EngineKind {
    if f.nthreads <= 1 || f.n < 2048 {
        return EngineKind::Sequential;
    }
    if f.scatter_ratio == 0.0 {
        return EngineKind::LocalBuffers(AccumMethod::Effective);
    }
    if f.colors <= 2 {
        return EngineKind::Colorful;
    }
    if f.intervals > 4 * f.nthreads.max(1) {
        EngineKind::LocalBuffers(AccumMethod::Interval)
    } else {
        EngineKind::LocalBuffers(AccumMethod::Effective)
    }
}

/// Run the measured trials and pick a winner. `plan` must carry the
/// pieces every candidate at its thread count borrows
/// ([`required_pieces`]; `PlanBuilder::all` always suffices); panics
/// otherwise (programming error, same contract as [`build_engine`]).
pub fn tune(kernel: &Arc<dyn SpmvKernel>, plan: &Arc<SpmvPlan>, budget: &TrialBudget) -> Decision {
    tune_with_fingerprint(kernel, plan, budget, fingerprint(kernel.as_ref()), ReorderPolicy::Never)
}

/// [`tune`] with the reorder axis: under [`ReorderPolicy::Measure`] the
/// candidate set doubles ([`candidates_with_reorder`]) and the RCM
/// ordering competes on measured rate; under [`ReorderPolicy::Always`]
/// only the reordered candidates run (falling back to plain when the
/// kernel cannot permute or RCM is the identity).
pub fn tune_reordered(
    kernel: &Arc<dyn SpmvKernel>,
    plan: &Arc<SpmvPlan>,
    budget: &TrialBudget,
    policy: ReorderPolicy,
) -> Decision {
    tune_with_fingerprint(kernel, plan, budget, fingerprint(kernel.as_ref()), policy)
}

/// [`tune`] with a caller-supplied fingerprint, so [`resolve`] — which
/// already hashed the structure for its cache lookup — does not pay the
/// O(nnz) pass twice on a miss.
fn tune_with_fingerprint(
    kernel: &Arc<dyn SpmvKernel>,
    plan: &Arc<SpmvPlan>,
    budget: &TrialBudget,
    fp: u64,
    policy: ReorderPolicy,
) -> Decision {
    assert!(
        plan.pieces.covers(required_pieces(plan.nthreads)),
        "the tuner trials every candidate engine: build the plan with \
         PlanBuilder::all or tuner::required_pieces"
    );
    let t0 = Instant::now();
    let features = Features::extract(kernel.as_ref(), plan);
    if budget.is_zero() {
        let kind = cost_model(&features);
        return Decision {
            kind,
            // Without trials the only honest "always" is to honour the
            // caller's forced ordering; Measure degrades to plain.
            reorder: policy == ReorderPolicy::Always,
            mflops: 0.0,
            measured: false,
            tuned_s: t0.elapsed().as_secs_f64(),
            fingerprint: fp,
            nthreads: plan.nthreads,
            max_threads: plan.nthreads,
            features,
            trials: Vec::new(),
            sweep: Vec::new(),
        };
    }
    let work = features.work_flops;
    let rctx = if policy == ReorderPolicy::Never { None } else { reorder_context(kernel, plan) };
    let cands = candidates_with_reorder(plan.nthreads, rctx.is_some());
    let mut trials = Vec::new();
    if policy != ReorderPolicy::Always || rctx.is_none() {
        let plain: Vec<EngineKind> =
            cands.iter().filter(|c| !c.reordered).map(|c| c.kind).collect();
        trials.extend(measure_candidates(kernel, plan, budget, work, &plain));
    }
    if let Some((pk, pplan, perm)) = &rctx {
        let reord: Vec<EngineKind> =
            cands.iter().filter(|c| c.reordered).map(|c| c.kind).collect();
        trials.extend(measure_reordered_candidates(pk, pplan, perm, budget, work, &reord));
    }
    let best = best_trial(&trials);
    Decision {
        kind: best.kind,
        reorder: best.reordered,
        mflops: best.mflops,
        measured: true,
        tuned_s: t0.elapsed().as_secs_f64(),
        fingerprint: fp,
        nthreads: plan.nthreads,
        max_threads: plan.nthreads,
        features,
        trials,
        sweep: Vec::new(),
    }
}

/// Measure every kind in `kinds` over the shared plan. Each engine runs
/// one *untimed* warm-up product first: the first timed run would
/// otherwise pay pool spin-up and cold caches, biasing the comparison
/// against whichever candidate happens to run first (and, under a
/// one-run budget where the median cannot shrug the cold run off,
/// against every pool-backed engine).
fn measure_candidates(
    kernel: &Arc<dyn SpmvKernel>,
    plan: &Arc<SpmvPlan>,
    budget: &TrialBudget,
    work: usize,
    kinds: &[EngineKind],
) -> Vec<TrialResult> {
    let n = kernel.dim();
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.001).sin()).collect();
    let mut y = vec![0.0; n];
    let mut trials = Vec::with_capacity(kinds.len());
    for &kind in kinds {
        let mut engine = build_engine(kind, kernel.clone(), plan.clone());
        engine.spmv(&x, &mut y);
        let (per, mad) = metrics::median_and_spread_of_runs(budget.runs, budget.products, || {
            engine.spmv(&x, &mut y)
        });
        trials.push(TrialResult {
            kind,
            reordered: false,
            seconds_per_product: per,
            mad_s: mad,
            mflops: metrics::mflops(work, per),
        });
    }
    trials
}

/// The reorder trial context: the permuted kernel, a plan built for it,
/// and the permutation — or `None` when the kernel cannot permute
/// (formats without [`SpmvKernel::permuted`]) or RCM cannot tighten the
/// band (an already well-ordered matrix: reordering would only add the
/// per-product gather cost, so there is nothing worth measuring).
/// Prefers the analysis the plan's reorder stage already computed.
fn reorder_context(
    kernel: &Arc<dyn SpmvKernel>,
    plan: &SpmvPlan,
) -> Option<(Arc<dyn SpmvKernel>, Arc<SpmvPlan>, Arc<Permutation>)> {
    let (perm, hbw_before, hbw_after) = match &plan.reorder {
        Some(r) => (r.perm.clone(), r.hbw_before, r.hbw_after),
        None => {
            let r = reorder::analyze(kernel.as_ref());
            (r.perm, r.hbw_before, r.hbw_after)
        }
    };
    if hbw_after >= hbw_before {
        return None;
    }
    let permuted = kernel.permuted(&perm)?;
    let pieces = PlanPieces { reorder: false, ..plan.pieces };
    let pplan =
        Arc::new(PlanBuilder::new(plan.nthreads).with_pieces(pieces).build(permuted.as_ref()));
    Some((permuted, pplan, perm))
}

/// [`measure_candidates`] through the RCM ordering: engines are built
/// over the permuted kernel and timed behind a [`ReorderedEngine`], so
/// the per-product permute/un-permute gathers count against the
/// reordered candidates — the comparison with the plain trials is
/// end-to-end honest.
fn measure_reordered_candidates(
    permuted: &Arc<dyn SpmvKernel>,
    plan: &Arc<SpmvPlan>,
    perm: &Arc<Permutation>,
    budget: &TrialBudget,
    work: usize,
    kinds: &[EngineKind],
) -> Vec<TrialResult> {
    let n = permuted.dim();
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.001).sin()).collect();
    let mut y = vec![0.0; n];
    let mut trials = Vec::with_capacity(kinds.len());
    for &kind in kinds {
        let inner = build_engine(kind, permuted.clone(), plan.clone());
        let mut engine = ReorderedEngine::new(inner, perm.clone());
        engine.spmv(&x, &mut y); // untimed warm-up, as for plain trials
        let (per, mad) = metrics::median_and_spread_of_runs(budget.runs, budget.products, || {
            engine.spmv(&x, &mut y)
        });
        trials.push(TrialResult {
            kind,
            reordered: true,
            seconds_per_product: per,
            mad_s: mad,
            mflops: metrics::mflops(work, per),
        });
    }
    trials
}

fn best_trial(trials: &[TrialResult]) -> &TrialResult {
    trials
        .iter()
        .max_by(|a, b| a.mflops.partial_cmp(&b.mflops).expect("rates are finite"))
        .expect("candidates is never empty")
}

/// Two-dimensional tuning: trial every candidate engine at every thread
/// count of `ladder`, returning the `(engine, nthreads)` argmax plus the
/// full sweep surface. `plan_for(p)` supplies the shared plan at p —
/// typically [`crate::plan::PlanCache::get_or_build`], so sweeping a
/// registered matrix reuses one analysis per thread count; each returned
/// plan must be built at p and cover [`required_pieces`]`(p)`.
///
/// The paper's §4 scalability curves motivate the second dimension:
/// several matrices peak below the machine's core count (memory
/// bandwidth saturates first), so tuning the engine at one fixed p
/// leaves rate on the table — measurement must pick p too.
pub fn sweep(
    kernel: &Arc<dyn SpmvKernel>,
    ladder: &[usize],
    budget: &TrialBudget,
    plan_for: &mut dyn FnMut(usize) -> Arc<SpmvPlan>,
) -> Decision {
    sweep_with_fingerprint(
        kernel,
        ladder,
        budget,
        plan_for,
        fingerprint(kernel.as_ref()),
        ReorderPolicy::Never,
    )
}

/// [`sweep`] with the reorder axis: every ladder rung trials the plain
/// *and* the RCM-reordered candidates, so reorder-on vs reorder-off is
/// measured per matrix across the whole (engine × p) surface. The
/// permuted kernel's per-rung plans are built locally (they belong to
/// the reordered structure, not the caller's plan cache key).
pub fn sweep_reordered(
    kernel: &Arc<dyn SpmvKernel>,
    ladder: &[usize],
    budget: &TrialBudget,
    plan_for: &mut dyn FnMut(usize) -> Arc<SpmvPlan>,
    policy: ReorderPolicy,
) -> Decision {
    sweep_with_fingerprint(kernel, ladder, budget, plan_for, fingerprint(kernel.as_ref()), policy)
}

fn sweep_with_fingerprint(
    kernel: &Arc<dyn SpmvKernel>,
    ladder: &[usize],
    budget: &TrialBudget,
    plan_for: &mut dyn FnMut(usize) -> Arc<SpmvPlan>,
    fp: u64,
    policy: ReorderPolicy,
) -> Decision {
    assert!(!ladder.is_empty(), "thread ladder must name at least one thread count");
    let max = ladder.iter().copied().max().unwrap_or(1);
    let t0 = Instant::now();
    let plan_max = plan_for(max);
    assert!(
        plan_max.nthreads == max && plan_max.pieces.covers(required_pieces(max)),
        "plan_for must honour the requested thread count and tuner::required_pieces"
    );
    let features = Features::extract(kernel.as_ref(), &plan_max);
    if budget.is_zero() {
        let kind = cost_model(&features);
        // The heuristic has no p axis: sequential runs at 1 thread,
        // everything else at the full budget.
        let nthreads = if kind == EngineKind::Sequential { 1 } else { max };
        return Decision {
            kind,
            reorder: policy == ReorderPolicy::Always,
            mflops: 0.0,
            measured: false,
            tuned_s: t0.elapsed().as_secs_f64(),
            fingerprint: fp,
            nthreads,
            max_threads: max,
            features,
            trials: Vec::new(),
            sweep: Vec::new(),
        };
    }
    let work = features.work_flops;
    // Reorder context shared across rungs: the permutation and permuted
    // kernel are p-independent; only the plan is rebuilt per rung.
    let rctx = if policy == ReorderPolicy::Never {
        None
    } else {
        reorder_context(kernel, &plan_max)
    };
    let skip_plain = policy == ReorderPolicy::Always && rctx.is_some();
    let mut sweep: Vec<SweepPoint> = Vec::with_capacity(ladder.len());
    // The sequential sweeps (plain and reordered) ignore the plan's
    // thread count, so one measurement each — taken at the first rung —
    // serves every rung; without this the usually-slowest candidates
    // would be re-timed per rung.
    let mut seq_trial: Option<TrialResult> = None;
    let mut seq_trial_reordered: Option<TrialResult> = None;
    for &p in ladder {
        if sweep.iter().any(|pt| pt.nthreads == p) {
            continue; // a duplicated rung buys no information
        }
        let plan = if p == max { plan_max.clone() } else { plan_for(p) };
        assert!(
            plan.nthreads == p && plan.pieces.covers(required_pieces(p)),
            "plan_for must honour the requested thread count and tuner::required_pieces"
        );
        let mut trials: Vec<TrialResult> = Vec::new();
        if !skip_plain {
            let mut kinds = candidates(p);
            if seq_trial.is_some() {
                kinds.retain(|k| *k != EngineKind::Sequential);
            }
            trials = measure_candidates(kernel, &plan, budget, work, &kinds);
            match &seq_trial {
                Some(t) => trials.insert(0, t.clone()),
                None => {
                    seq_trial =
                        trials.iter().find(|t| t.kind == EngineKind::Sequential).cloned();
                }
            }
        }
        if let Some((pk, pplan_max, perm)) = &rctx {
            let pplan = if p == max {
                pplan_max.clone()
            } else {
                Arc::new(
                    PlanBuilder::new(p).with_pieces(required_pieces(p)).build(pk.as_ref()),
                )
            };
            let mut kinds = candidates(p);
            if seq_trial_reordered.is_some() {
                kinds.retain(|k| *k != EngineKind::Sequential);
            }
            let start = trials.len();
            trials.extend(measure_reordered_candidates(pk, &pplan, perm, budget, work, &kinds));
            match &seq_trial_reordered {
                Some(t) => trials.insert(start, t.clone()),
                None => {
                    seq_trial_reordered = trials[start..]
                        .iter()
                        .find(|t| t.kind == EngineKind::Sequential)
                        .cloned();
                }
            }
        }
        sweep.push(SweepPoint { nthreads: p, trials });
    }
    let (best_p, best_kind, best_reorder, best_mflops) = sweep
        .iter()
        .map(|pt| {
            let b = pt.best().expect("candidates is never empty");
            (pt.nthreads, b.kind, b.reordered, b.mflops)
        })
        .max_by(|a, b| a.3.partial_cmp(&b.3).expect("rates are finite"))
        .expect("ladder is never empty");
    let trials = sweep
        .iter()
        .find(|pt| pt.nthreads == best_p)
        .expect("winner rung exists")
        .trials
        .clone();
    Decision {
        kind: best_kind,
        reorder: best_reorder,
        mflops: best_mflops,
        measured: true,
        tuned_s: t0.elapsed().as_secs_f64(),
        fingerprint: fp,
        nthreads: best_p,
        max_threads: max,
        features,
        trials,
        sweep,
    }
}

/// Cache-fronted [`tune`]: returns the decision plus whether it came
/// from the cache (`true` = zero new trials were run).
///
/// A cached *unmeasured* (cost-model) decision does not satisfy a caller
/// that brought a measuring budget: it is re-tuned and the cache entry
/// upgraded — otherwise one zero-budget call would freeze the heuristic
/// pick forever. A cached *swept* decision satisfies a single-p caller
/// through [`single_p_view`]: the caller asked for `plan.nthreads`
/// threads, so it gets that rung's winner, not the sweep's global
/// (possibly lower-p) pick — re-tuning would let sweeping and
/// non-sweeping callers endlessly overwrite each other's entries.
/// A cached *measured* entry satisfies any reorder policy — re-tuning
/// on every policy change would thrash the cache — with one exception:
/// `Never` is an explicit off switch, so a cached reordered winner is
/// demoted through [`never_view`] instead of silently re-enabling the
/// permute/un-permute path. Callers wanting fresh reorder trials bring
/// a fresh cache file.
pub fn resolve(
    kernel: &Arc<dyn SpmvKernel>,
    plan: &Arc<SpmvPlan>,
    budget: &TrialBudget,
    cache: &DecisionCache,
    policy: ReorderPolicy,
) -> (Decision, bool) {
    let fp = fingerprint(kernel.as_ref());
    if let Some(d) = cache.peek(fp, plan.nthreads) {
        if d.measured || budget.is_zero() {
            cache.record(true);
            return (never_view(single_p_view(d, plan.nthreads), policy), true);
        }
    }
    cache.record(false);
    let d = tune_with_fingerprint(kernel, plan, budget, fp, policy);
    cache.put(d.clone());
    (d, false)
}

/// A `Never` caller's view of a cached decision: reordered execution is
/// an opt-in, so a cached reordered winner is demoted to the best
/// *plain* measurement — searched across the whole sweep surface when
/// one was recorded (the plain optimum may sit at a different thread
/// count than the reordered winner). An entry written under `Always`
/// has no plain trials at all: the engine pick is kept (every engine
/// runs correctly in the given ordering) but the recorded rate is
/// cleared, since it was measured through the reordering and would
/// otherwise arm the drift detector against an unreachable baseline.
fn never_view(mut d: Decision, policy: ReorderPolicy) -> Decision {
    if policy != ReorderPolicy::Never || !d.reorder {
        return d;
    }
    let mut best: Option<(usize, EngineKind, f64)> = None;
    let mut consider = |p: usize, t: &TrialResult| {
        if !t.reordered && best.map_or(true, |(_, _, m)| t.mflops > m) {
            best = Some((p, t.kind, t.mflops));
        }
    };
    if d.sweep.is_empty() {
        for t in &d.trials {
            consider(d.nthreads, t);
        }
    } else {
        for pt in &d.sweep {
            for t in &pt.trials {
                consider(pt.nthreads, t);
            }
        }
    }
    match best {
        Some((p, kind, mflops)) => {
            d.kind = kind;
            d.mflops = mflops;
            d.nthreads = p;
        }
        None => d.mflops = 0.0,
    }
    d.reorder = false;
    d
}

/// A single-p caller's view of a cached decision. Swept entries answer
/// with their rung at exactly `p` — the cache key's `max_threads` *is*
/// the caller's thread count, so that rung was measured — which keeps
/// the caller's thread-count contract (`RoutePolicy::threads` means "run
/// at p" unless `sweep_threads` opted into per-matrix picks). Single-p
/// entries, and a sweep whose winner already sits at `p`, pass through
/// unchanged; a malformed surface with no rung at `p` (hand-edited
/// file) is served as recorded.
fn single_p_view(d: Decision, p: usize) -> Decision {
    if d.sweep.is_empty() || d.nthreads == p {
        return d;
    }
    let best = d
        .sweep
        .iter()
        .find(|pt| pt.nthreads == p)
        .and_then(|pt| pt.best().map(|b| (b.kind, b.reordered, b.mflops, pt.trials.clone())));
    match best {
        Some((kind, reorder, mflops, trials)) => {
            Decision { kind, reorder, mflops, nthreads: p, trials, ..d }
        }
        None => d,
    }
}

/// Cache-fronted [`sweep`], keyed by (fingerprint × the ladder's max
/// thread count). Same upgrade ladder as [`resolve`], one rung higher:
/// an unmeasured entry never satisfies a measuring caller, and a
/// measured *single-p* entry (a v1 cache file, or a plain [`tune`] at
/// the same thread budget) does not satisfy a caller asking for the
/// thread sweep — it is re-swept and the entry upgraded in place.
pub fn resolve_swept(
    kernel: &Arc<dyn SpmvKernel>,
    ladder: &[usize],
    budget: &TrialBudget,
    cache: &DecisionCache,
    plan_for: &mut dyn FnMut(usize) -> Arc<SpmvPlan>,
    policy: ReorderPolicy,
) -> (Decision, bool) {
    let fp = fingerprint(kernel.as_ref());
    let max = ladder.iter().copied().max().unwrap_or(1);
    if let Some(d) = cache.peek(fp, max) {
        if budget.is_zero() || (d.measured && !d.sweep.is_empty()) {
            cache.record(true);
            return (never_view(d, policy), true);
        }
    }
    cache.record(false);
    let d = sweep_with_fingerprint(kernel, ladder, budget, plan_for, fp, policy);
    cache.put(d.clone());
    (d, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::PlanBuilder;
    use crate::sparse::{Coo, Csr, Csrc};
    use crate::util::Rng;

    fn kernel_and_plan(n: usize, seed: u64, p: usize) -> (Arc<dyn SpmvKernel>, Arc<SpmvPlan>) {
        let mut rng = Rng::new(seed);
        let coo = Coo::random_structurally_symmetric(n, 4, false, &mut rng);
        let kernel: Arc<dyn SpmvKernel> = Arc::new(Csrc::from_coo(&coo).unwrap());
        let plan = Arc::new(PlanBuilder::all(p).build(kernel.as_ref()));
        (kernel, plan)
    }

    #[test]
    fn tune_picks_a_measured_concrete_winner() {
        let (kernel, plan) = kernel_and_plan(150, 1, 2);
        let d = tune(&kernel, &plan, &TrialBudget::smoke());
        assert!(d.measured);
        assert!(!d.reorder, "plain tune never picks the reordered axis");
        assert!(d.trials.iter().all(|t| !t.reordered));
        assert_ne!(d.kind, EngineKind::Auto);
        assert_eq!(d.trials.len(), candidates(2).len());
        assert!(d.mflops > 0.0);
        assert!(d.tuned_s > 0.0);
        // The winner really is the argmax of the trials.
        let best = d.trials.iter().map(|t| t.mflops).fold(0.0, f64::max);
        assert_eq!(d.mflops, best);
        assert_eq!(d.nthreads, 2);
        // Single-p decisions: the thread budget equals the pick, and
        // there is no sweep surface.
        assert_eq!(d.max_threads, 2);
        assert!(d.sweep.is_empty());
        assert_eq!(d.fingerprint, fingerprint(kernel.as_ref()));
    }

    #[test]
    fn thread_ladder_doubles_up_to_max() {
        assert_eq!(thread_ladder(1), vec![1]);
        assert_eq!(thread_ladder(2), vec![1, 2]);
        assert_eq!(thread_ladder(4), vec![1, 2, 4]);
        assert_eq!(thread_ladder(6), vec![1, 2, 4, 6]);
        assert_eq!(thread_ladder(0), vec![1], "zero budget degrades to one thread");
    }

    #[test]
    fn sweep_picks_the_global_argmax_over_engines_and_threads() {
        let (kernel, _) = kernel_and_plan(150, 6, 2);
        let plans = crate::plan::PlanCache::new();
        let ladder = thread_ladder(2);
        let mut plan_for = cached_plan_provider(&plans, "m", &kernel);
        let d = sweep(&kernel, &ladder, &TrialBudget::smoke(), &mut plan_for);
        assert!(d.measured);
        assert_eq!(d.max_threads, 2);
        assert_eq!(d.sweep.len(), 2, "one point per ladder rung");
        assert_eq!(d.sweep[0].nthreads, 1);
        assert_eq!(d.sweep[0].trials.len(), candidates(1).len());
        assert_eq!(d.sweep[1].nthreads, 2);
        assert_eq!(d.sweep[1].trials.len(), candidates(2).len());
        assert!(d.nthreads == 1 || d.nthreads == 2);
        // The decision really is the argmax over the whole surface, and
        // `trials` mirrors the winning rung.
        let best = d
            .sweep
            .iter()
            .flat_map(|pt| pt.trials.iter().map(|t| t.mflops))
            .fold(0.0, f64::max);
        assert_eq!(d.mflops, best);
        let rung = d.sweep.iter().find(|pt| pt.nthreads == d.nthreads).unwrap();
        assert!(rung.trials.iter().any(|t| t.kind == d.kind && t.mflops == d.mflops));
        // One shared analysis per rung, no more.
        assert_eq!(plans.builds(), 2);
    }

    #[test]
    fn sweep_zero_budget_answers_from_cost_model() {
        let (kernel, _) = kernel_and_plan(100, 7, 3);
        let plans = crate::plan::PlanCache::new();
        let mut plan_for = cached_plan_provider(&plans, "m", &kernel);
        let d = sweep(&kernel, &thread_ladder(3), &TrialBudget::zero(), &mut plan_for);
        assert!(!d.measured && d.sweep.is_empty() && d.trials.is_empty());
        // n=100 < the fork-join threshold → sequential at one thread.
        assert_eq!(d.kind, EngineKind::Sequential);
        assert_eq!(d.nthreads, 1);
        assert_eq!(d.max_threads, 3);
    }

    #[test]
    fn resolve_swept_upgrades_single_p_entries_and_then_hits() {
        let (kernel, plan) = kernel_and_plan(130, 8, 2);
        let cache = DecisionCache::in_memory();
        // A plain single-p tune at the same thread budget…
        let (d0, hit0) = resolve(&kernel, &plan, &TrialBudget::smoke(), &cache, ReorderPolicy::Never);
        assert!(!hit0 && d0.measured && d0.sweep.is_empty());
        let plans = crate::plan::PlanCache::new();
        let mut plan_for = cached_plan_provider(&plans, "m", &kernel);
        // …does not satisfy a sweeping caller with a measuring budget:
        // the entry is upgraded in place with the full surface.
        let ladder = thread_ladder(2);
        let (d1, hit1) = resolve_swept(
            &kernel,
            &ladder,
            &TrialBudget::smoke(),
            &cache,
            &mut plan_for,
            ReorderPolicy::Never,
        );
        assert!(!hit1 && d1.measured && !d1.sweep.is_empty());
        assert_eq!(cache.len(), 1, "the swept decision replaces the single-p entry");
        // From now on, sweeping callers hit.
        let (d2, hit2) = resolve_swept(
            &kernel,
            &ladder,
            &TrialBudget::smoke(),
            &cache,
            &mut plan_for,
            ReorderPolicy::Never,
        );
        assert!(hit2);
        assert_eq!(d2.kind, d1.kind);
        assert_eq!(d2.nthreads, d1.nthreads);
        // A zero-budget sweeping caller is happy with whatever is there.
        let (_, hit3) = resolve_swept(
            &kernel,
            &ladder,
            &TrialBudget::zero(),
            &cache,
            &mut plan_for,
            ReorderPolicy::Never,
        );
        assert!(hit3);
    }

    #[test]
    fn resolve_serves_single_p_view_of_swept_entries() {
        // A swept entry whose global winner sits at p=1 must not leak
        // that thread count to a non-sweeping caller who asked for p=2:
        // the caller gets the p=2 rung's winner (RoutePolicy::threads
        // keeps its meaning unless sweep_threads opted in).
        let (kernel, plan) = kernel_and_plan(140, 10, 2);
        let cache = DecisionCache::in_memory();
        let fp = fingerprint(kernel.as_ref());
        let seq = TrialResult {
            kind: EngineKind::Sequential,
            reordered: false,
            seconds_per_product: 1e-4,
            mad_s: 0.0,
            mflops: 120.0,
        };
        let rung2 = vec![
            TrialResult {
                kind: EngineKind::Atomic,
                reordered: false,
                seconds_per_product: 2e-4,
                mad_s: 0.0,
                mflops: 40.0,
            },
            TrialResult {
                kind: EngineKind::Colorful,
                reordered: false,
                seconds_per_product: 1e-4,
                mad_s: 0.0,
                mflops: 80.0,
            },
        ];
        cache.put(Decision {
            kind: EngineKind::Sequential,
            reorder: false,
            mflops: 120.0,
            measured: true,
            tuned_s: 0.01,
            fingerprint: fp,
            nthreads: 1,
            max_threads: 2,
            features: Features::extract(kernel.as_ref(), &plan),
            trials: vec![seq.clone()],
            sweep: vec![
                SweepPoint { nthreads: 1, trials: vec![seq] },
                SweepPoint { nthreads: 2, trials: rung2 },
            ],
        });
        let (d, hit) =
            resolve(&kernel, &plan, &TrialBudget::smoke(), &cache, ReorderPolicy::Never);
        assert!(hit, "the swept entry satisfies the single-p caller");
        assert_eq!(d.nthreads, 2, "the view answers at the caller's thread count");
        assert_eq!(d.kind, EngineKind::Colorful, "…with that rung's winner");
        assert_eq!(d.mflops, 80.0);
        assert_eq!(d.trials.len(), 2, "…and that rung's trials");
    }

    #[test]
    fn sweep_measures_sequential_once() {
        // The sequential sweep ignores p — its trial is taken at the
        // first rung and reused, so every rung still reports it but the
        // identical measurement is not repeated.
        let (kernel, _) = kernel_and_plan(150, 11, 2);
        let plans = crate::plan::PlanCache::new();
        let mut plan_for = cached_plan_provider(&plans, "m", &kernel);
        let d = sweep(&kernel, &thread_ladder(2), &TrialBudget::smoke(), &mut plan_for);
        let seq1 = d.sweep[0].trials.iter().find(|t| t.kind == EngineKind::Sequential).unwrap();
        let seq2 = d.sweep[1].trials.iter().find(|t| t.kind == EngineKind::Sequential).unwrap();
        assert_eq!(seq1.seconds_per_product, seq2.seconds_per_product);
        assert_eq!(seq1.mflops, seq2.mflops);
    }

    #[test]
    fn duplicated_candidates_measure_consistently() {
        // Regression guard for the cold-start bias: with one untimed
        // warm-up product per engine, a duplicated candidate cannot be
        // penalized for running first (pool spin-up, cold caches) even
        // under a one-run budget where the median cannot absorb it.
        let (kernel, plan) = kernel_and_plan(3000, 9, 2);
        let kind = EngineKind::LocalBuffers(AccumMethod::Effective);
        let trials = measure_candidates(
            &kernel,
            &plan,
            &TrialBudget { runs: 1, products: 4 },
            Features::extract(kernel.as_ref(), &plan).work_flops,
            &[kind, kind, kind],
        );
        assert_eq!(trials.len(), 3);
        let rates: Vec<f64> = trials.iter().map(|t| t.mflops).collect();
        let lo = rates.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = rates.iter().cloned().fold(0.0, f64::max);
        assert!(lo > 0.0);
        assert!(
            hi / lo < 5.0,
            "duplicated candidates must measure consistently, got {rates:?}"
        );
    }

    #[test]
    fn zero_budget_answers_from_cost_model() {
        let (kernel, plan) = kernel_and_plan(100, 2, 3);
        let d = tune(&kernel, &plan, &TrialBudget::zero());
        assert!(!d.measured);
        assert!(d.trials.is_empty());
        assert_ne!(d.kind, EngineKind::Auto);
        // n=100 < the fork-join threshold → sequential.
        assert_eq!(d.kind, EngineKind::Sequential);
    }

    #[test]
    fn cost_model_prefers_effective_for_scatter_free() {
        let mut rng = Rng::new(3);
        let coo = Coo::random_structurally_symmetric(5000, 3, false, &mut rng);
        let csr = Csr::from_coo(&coo);
        let plan = PlanBuilder::all(4).build(&csr);
        let f = Features::extract(&csr, &plan);
        assert_eq!(cost_model(&f), EngineKind::LocalBuffers(AccumMethod::Effective));
    }

    #[test]
    fn resolve_runs_once_then_hits_the_cache() {
        let (kernel, plan) = kernel_and_plan(120, 4, 2);
        let cache = DecisionCache::in_memory();
        let (d1, hit1) =
            resolve(&kernel, &plan, &TrialBudget::smoke(), &cache, ReorderPolicy::Never);
        assert!(!hit1);
        let (d2, hit2) =
            resolve(&kernel, &plan, &TrialBudget::smoke(), &cache, ReorderPolicy::Never);
        assert!(hit2, "second resolve of the same structure must not re-tune");
        assert_eq!(d1.kind, d2.kind);
        // A different thread count is a different decision.
        let plan3 = Arc::new(PlanBuilder::all(3).build(kernel.as_ref()));
        let (_, hit3) =
            resolve(&kernel, &plan3, &TrialBudget::smoke(), &cache, ReorderPolicy::Never);
        assert!(!hit3);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn reordered_candidates_double_the_set() {
        let plain = candidates_with_reorder(4, false);
        assert_eq!(plain.len(), candidates(4).len());
        assert!(plain.iter().all(|c| !c.reordered));
        let both = candidates_with_reorder(4, true);
        assert_eq!(both.len(), 2 * candidates(4).len());
        assert_eq!(both.iter().filter(|c| c.reordered).count(), candidates(4).len());
    }

    #[test]
    fn tune_reordered_measures_both_orderings() {
        // A shuffled band: RCM has real room, so the reordered trials
        // are genuinely different engines. The winner is whichever
        // measured faster — asserted structurally, not by timing.
        let mut rng = Rng::new(21);
        let band = Csrc::from_coo(&Coo::banded(400, 2, false, &mut rng)).unwrap();
        let shuffle =
            crate::reorder::Permutation::from_new_to_old(rng.permutation(400)).unwrap();
        let shuffled = band.permuted(&shuffle);
        let kernel: Arc<dyn SpmvKernel> = Arc::new(shuffled);
        let plan = Arc::new(PlanBuilder::all(2).build(kernel.as_ref()));
        let d = tune_reordered(&kernel, &plan, &TrialBudget::smoke(), ReorderPolicy::Measure);
        assert!(d.measured);
        assert_eq!(d.trials.len(), 2 * candidates(2).len());
        assert!(d.trials.iter().any(|t| t.reordered));
        assert!(d.trials.iter().any(|t| !t.reordered));
        // The decision's reorder flag is the winning trial's.
        let best = d
            .trials
            .iter()
            .max_by(|a, b| a.mflops.partial_cmp(&b.mflops).unwrap())
            .unwrap();
        assert_eq!(d.reorder, best.reordered);
        assert_eq!(d.kind, best.kind);
        // Always restricts the search to the reordered half.
        let da = tune_reordered(&kernel, &plan, &TrialBudget::smoke(), ReorderPolicy::Always);
        assert!(da.reorder && da.trials.iter().all(|t| t.reordered));
        // Never stays plain even on the same matrix.
        let dn = tune_reordered(&kernel, &plan, &TrialBudget::smoke(), ReorderPolicy::Never);
        assert!(!dn.reorder && dn.trials.iter().all(|t| !t.reordered));
    }

    #[test]
    fn sweep_reordered_covers_every_rung_with_both_orderings() {
        let mut rng = Rng::new(22);
        let band = Csrc::from_coo(&Coo::banded(300, 2, false, &mut rng)).unwrap();
        let shuffle =
            crate::reorder::Permutation::from_new_to_old(rng.permutation(300)).unwrap();
        let kernel: Arc<dyn SpmvKernel> = Arc::new(band.permuted(&shuffle));
        let plans = crate::plan::PlanCache::new();
        let mut plan_for = cached_plan_provider(&plans, "m", &kernel);
        let d = sweep_reordered(
            &kernel,
            &thread_ladder(2),
            &TrialBudget::smoke(),
            &mut plan_for,
            ReorderPolicy::Measure,
        );
        assert!(d.measured);
        assert_eq!(d.sweep.len(), 2);
        for pt in &d.sweep {
            assert_eq!(pt.trials.len(), 2 * candidates(pt.nthreads).len());
            assert!(pt.trials.iter().any(|t| t.reordered));
            assert!(pt.trials.iter().any(|t| !t.reordered));
        }
        // The reordered sequential trial, like the plain one, is
        // measured once and shared across rungs.
        let rs1 = d.sweep[0]
            .trials
            .iter()
            .find(|t| t.kind == EngineKind::Sequential && t.reordered)
            .unwrap();
        let rs2 = d.sweep[1]
            .trials
            .iter()
            .find(|t| t.kind == EngineKind::Sequential && t.reordered)
            .unwrap();
        assert_eq!(rs1.seconds_per_product, rs2.seconds_per_product);
    }

    #[test]
    fn reorder_context_skips_unimprovable_orderings() {
        // A matrix RCM cannot improve (diagonal: bandwidth already 0)
        // yields no reorder context — the gather cost would buy nothing.
        let mut coo = Coo::new(6, 6);
        for i in 0..6 {
            coo.push(i, i, 2.0);
        }
        coo.compact();
        let kernel: Arc<dyn SpmvKernel> = Arc::new(Csrc::from_coo(&coo).unwrap());
        let plan = Arc::new(PlanBuilder::all(2).build(kernel.as_ref()));
        assert!(reorder_context(&kernel, &plan).is_none());
        // Tuning with Always on such a kernel falls back to plain trials.
        let d = tune_reordered(&kernel, &plan, &TrialBudget::smoke(), ReorderPolicy::Always);
        assert!(!d.reorder);
        assert!(d.trials.iter().all(|t| !t.reordered));
    }

    #[test]
    fn never_policy_demotes_cached_reordered_winners() {
        // A cache written under `Measure` records a reordered winner; a
        // later `Never` caller must get a plain decision (best plain
        // trial), not a silently re-enabled permute/un-permute path.
        let (kernel, plan) = kernel_and_plan(120, 12, 2);
        let cache = DecisionCache::in_memory();
        let fp = fingerprint(kernel.as_ref());
        let trials = vec![
            TrialResult {
                kind: EngineKind::Colorful,
                reordered: false,
                seconds_per_product: 2e-4,
                mad_s: 0.0,
                mflops: 50.0,
            },
            TrialResult {
                kind: EngineKind::LocalBuffers(AccumMethod::Effective),
                reordered: true,
                seconds_per_product: 1e-4,
                mad_s: 0.0,
                mflops: 100.0,
            },
        ];
        cache.put(Decision {
            kind: EngineKind::LocalBuffers(AccumMethod::Effective),
            reorder: true,
            mflops: 100.0,
            measured: true,
            tuned_s: 0.01,
            fingerprint: fp,
            nthreads: 2,
            max_threads: 2,
            features: Features::extract(kernel.as_ref(), &plan),
            trials,
            sweep: Vec::new(),
        });
        let (d, hit) =
            resolve(&kernel, &plan, &TrialBudget::smoke(), &cache, ReorderPolicy::Never);
        assert!(hit, "the measured entry still satisfies the caller");
        assert!(!d.reorder, "Never must clear the reorder flag");
        assert_eq!(d.kind, EngineKind::Colorful, "…and demote to the best plain trial");
        assert_eq!(d.mflops, 50.0);
        // A Measure caller keeps the recorded reordered winner.
        let (d2, hit2) =
            resolve(&kernel, &plan, &TrialBudget::smoke(), &cache, ReorderPolicy::Measure);
        assert!(hit2 && d2.reorder);
        assert_eq!(d2.kind, EngineKind::LocalBuffers(AccumMethod::Effective));
    }

    #[test]
    fn single_thread_candidates_are_sequential_only() {
        assert_eq!(candidates(1), vec![EngineKind::Sequential]);
        assert!(candidates(4).contains(&EngineKind::Colorful));
        assert!(candidates(4).contains(&EngineKind::Atomic));
        assert!(!candidates(4).contains(&EngineKind::Auto));
        // One thread needs no analysis pieces; two need everything.
        assert_eq!(required_pieces(1), PlanPieces::default());
        assert_eq!(required_pieces(2), PlanPieces::all());
    }

    #[test]
    fn measured_budget_upgrades_a_cached_cost_model_decision() {
        let (kernel, plan) = kernel_and_plan(130, 5, 2);
        let cache = DecisionCache::in_memory();
        let (d0, hit0) =
            resolve(&kernel, &plan, &TrialBudget::zero(), &cache, ReorderPolicy::Never);
        assert!(!hit0 && !d0.measured);
        // Zero-budget callers keep hitting the heuristic entry...
        let (_, hit1) =
            resolve(&kernel, &plan, &TrialBudget::zero(), &cache, ReorderPolicy::Never);
        assert!(hit1);
        // ...but a measuring budget re-tunes instead of freezing it.
        let (d2, hit2) =
            resolve(&kernel, &plan, &TrialBudget::smoke(), &cache, ReorderPolicy::Never);
        assert!(!hit2 && d2.measured);
        // And the upgraded (measured) entry now satisfies everyone.
        let (d3, hit3) =
            resolve(&kernel, &plan, &TrialBudget::smoke(), &cache, ReorderPolicy::Never);
        assert!(hit3 && d3.measured);
    }
}
