//! JSON-backed persistent decision cache.
//!
//! Keyed by (structure [`super::fingerprint`] × thread *budget*
//! [`Decision::max_threads`]): a restarted service that re-registers a
//! known matrix reads its decision back and performs **zero** new
//! trials. The file is written through on every [`DecisionCache::put`];
//! a missing or corrupt file starts the cache empty rather than failing
//! — persisted decisions are a performance artifact, not a source of
//! truth.
//!
//! **Schema v2** (current): entries carry `max_threads` (the cache key's
//! second half) and the full `sweep` surface of [`super::SweepPoint`]s.
//! v1 files — written before the thread sweep existed — load without
//! error as single-p decisions: `max_threads` defaults to the entry's
//! `nthreads` and the sweep surface is empty, which is exactly what
//! [`super::resolve_swept`] treats as "upgrade me when a sweeping caller
//! brings a measuring budget".
//!
//! **Degradation rules** (the file is a performance artifact, never a
//! source of truth): a file that is not JSON, lacks the `decisions`
//! array, or was written by a *newer* schema than this build knows is
//! ignored wholesale — the cache starts empty with a warning. A single
//! malformed entry inside an otherwise healthy file is *skipped*, not
//! fatal: one corrupt record must not re-tune the whole fleet.

use super::{Decision, Features, Provenance, SweepPoint, TrialResult};
use crate::faults::{self, InjectionPoint};
use crate::parallel::EngineKind;
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

pub struct DecisionCache {
    path: Option<PathBuf>,
    map: Mutex<HashMap<(u64, usize), Decision>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl DecisionCache {
    /// Session-local cache with no backing file.
    pub fn in_memory() -> DecisionCache {
        DecisionCache {
            path: None,
            map: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Open (or create on first `put`) a persistent cache at `path`.
    /// A corrupt, truncated, wrong-version or otherwise unreadable file
    /// degrades to an empty cache with a warning — resolution must
    /// never abort on a damaged performance artifact.
    pub fn open(path: &Path) -> DecisionCache {
        // Chaos: a cache-io fault at open behaves exactly like an
        // unreadable file — warn, start empty, keep serving.
        let read = if faults::fire(InjectionPoint::CacheIo) {
            Err(std::io::Error::other("injected cache-io fault"))
        } else {
            std::fs::read_to_string(path)
        };
        let map = match read {
            // Genuinely absent: a fresh cache, nothing to warn about.
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => HashMap::new(),
            // Present but unreadable (permissions, EIO): warn loudly —
            // the next put() will overwrite whatever is there, and the
            // operator should know the accumulated decisions (and the
            // model-training corpus they form) are about to be lost.
            Err(e) => {
                eprintln!(
                    "warning: decision cache {} unreadable ({e}); starting empty",
                    path.display()
                );
                HashMap::new()
            }
            Ok(text) => match parse_decisions(&text) {
                Ok((map, 0)) => map,
                Ok((map, skipped)) => {
                    eprintln!(
                        "warning: decision cache {}: skipped {skipped} malformed entries",
                        path.display()
                    );
                    map
                }
                Err(e) => {
                    eprintln!(
                        "warning: decision cache {} ignored ({e}); starting empty",
                        path.display()
                    );
                    HashMap::new()
                }
            },
        };
        DecisionCache {
            path: Some(path.to_path_buf()),
            map: Mutex::new(map),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Look up by (fingerprint × thread budget). The second component is
    /// [`Decision::max_threads`] — for single-p decisions that equals
    /// the decision's `nthreads`; a swept decision is filed under the
    /// ladder's max even when its winning `nthreads` is smaller.
    pub fn get(&self, fingerprint: u64, max_threads: usize) -> Option<Decision> {
        let got = self.peek(fingerprint, max_threads);
        self.record(got.is_some());
        got
    }

    /// Counter-free lookup for [`super::resolve`], which decides the
    /// hit/miss accounting only after checking whether the entry
    /// actually satisfies the caller's budget (an unmeasured entry a
    /// measuring caller discards must not count as a hit).
    pub(super) fn peek(&self, fingerprint: u64, max_threads: usize) -> Option<Decision> {
        self.map.lock().unwrap().get(&(fingerprint, max_threads)).cloned()
    }

    pub(super) fn record(&self, hit: bool) {
        if hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Insert a decision and (when persistent) write the file through.
    /// Disk errors are swallowed: the in-memory cache stays authoritative
    /// for this process either way.
    pub fn put(&self, d: Decision) {
        let mut map = self.map.lock().unwrap();
        map.insert((d.fingerprint, d.max_threads), d);
        if let Some(path) = &self.path {
            if faults::fire(InjectionPoint::CacheIo) {
                eprintln!(
                    "warning: decision cache {} write skipped (injected cache-io fault)",
                    path.display()
                );
                return;
            }
            let _ = write_decisions(path, &map);
        }
    }

    /// Record the service's served-rate baseline into an entry (see
    /// [`Decision::served_mflops`]) and write the file through. A no-op
    /// when the entry has been replaced or evicted meanwhile.
    pub fn set_served_rate(&self, fingerprint: u64, max_threads: usize, mflops: f64) {
        let mut map = self.map.lock().unwrap();
        let Some(d) = map.get_mut(&(fingerprint, max_threads)) else { return };
        d.served_mflops = mflops;
        if let Some(path) = &self.path {
            if faults::fire(InjectionPoint::CacheIo) {
                eprintln!(
                    "warning: decision cache {} write skipped (injected cache-io fault)",
                    path.display()
                );
                return;
            }
            let _ = write_decisions(path, &map);
        }
    }

    /// Drop an entry's served-rate baseline (reset to 0) and write the
    /// file through. Called when a matrix's *values* change under a kept
    /// decision — replacement under an existing key, or an in-place
    /// `update_values` — because the baseline was measured against the
    /// old values: judging the new values against it could trigger or
    /// suppress a re-tune for the wrong reason. The next calibration
    /// window records a fresh baseline. A no-op when the entry is absent
    /// or has no baseline.
    pub fn clear_served_rate(&self, fingerprint: u64, max_threads: usize) {
        let mut map = self.map.lock().unwrap();
        let Some(d) = map.get_mut(&(fingerprint, max_threads)) else { return };
        if d.served_mflops == 0.0 {
            return;
        }
        d.served_mflops = 0.0;
        if let Some(path) = &self.path {
            if faults::fire(InjectionPoint::CacheIo) {
                eprintln!(
                    "warning: decision cache {} write skipped (injected cache-io fault)",
                    path.display()
                );
                return;
            }
            let _ = write_decisions(path, &map);
        }
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

fn features_to_json(f: &Features) -> Json {
    Json::obj(vec![
        ("n", Json::Num(f.n as f64)),
        ("work_flops", Json::Num(f.work_flops as f64)),
        ("scatter_pairs", Json::Num(f.scatter_pairs as f64)),
        ("scatter_ratio", Json::Num(f.scatter_ratio)),
        ("bandwidth", Json::Num(f.bandwidth as f64)),
        ("window_rows", Json::Num(f.window_rows as f64)),
        ("window_shrink", Json::Num(f.window_shrink)),
        ("colors", Json::Num(f.colors as f64)),
        ("intervals", Json::Num(f.intervals as f64)),
        ("balance", Json::Num(f.balance)),
        ("feat_nthreads", Json::Num(f.nthreads as f64)),
    ])
}

fn trial_to_json(t: &TrialResult) -> Json {
    Json::obj(vec![
        ("kind", Json::Str(t.kind.label())),
        ("reordered", Json::Bool(t.reordered)),
        ("seconds_per_product", Json::Num(t.seconds_per_product)),
        ("mad_s", Json::Num(t.mad_s)),
        ("mflops", Json::Num(t.mflops)),
    ])
}

fn sweep_point_to_json(pt: &SweepPoint) -> Json {
    Json::obj(vec![
        ("nthreads", Json::Num(pt.nthreads as f64)),
        ("trials", Json::Arr(pt.trials.iter().map(trial_to_json).collect())),
    ])
}

fn decision_to_json(d: &Decision) -> Json {
    Json::obj(vec![
        ("fingerprint", Json::Str(format!("{:016x}", d.fingerprint))),
        ("nthreads", Json::Num(d.nthreads as f64)),
        ("max_threads", Json::Num(d.max_threads as f64)),
        ("kind", Json::Str(d.kind.label())),
        ("reorder", Json::Bool(d.reorder)),
        ("mflops", Json::Num(d.mflops)),
        ("measured", Json::Bool(d.measured)),
        ("provenance", Json::Str(d.provenance.label().to_string())),
        ("served_mflops", Json::Num(d.served_mflops)),
        ("tuned_s", Json::Num(d.tuned_s)),
        ("features", features_to_json(&d.features)),
        ("trials", Json::Arr(d.trials.iter().map(trial_to_json).collect())),
        ("sweep", Json::Arr(d.sweep.iter().map(sweep_point_to_json).collect())),
        ("block_k", Json::Num(d.block_k as f64)),
        (
            "block_rates",
            Json::Arr(
                d.block_rates
                    .iter()
                    .map(|&(k, rate)| {
                        Json::obj(vec![
                            ("k", Json::Num(k as f64)),
                            ("mflops", Json::Num(rate)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// JSON form of one decision — the persisted v2 schema's entry shape,
/// exposed for CLI sweep reports (`csrc tune --report`).
pub fn decision_json(d: &Decision) -> Json {
    decision_to_json(d)
}

/// Current (write-side) schema version. Files claiming a *newer*
/// version are ignored wholesale: their entries may mean something this
/// build would misread.
const CACHE_VERSION: f64 = 2.0;

fn write_decisions(path: &Path, map: &HashMap<(u64, usize), Decision>) -> std::io::Result<()> {
    let mut entries: Vec<&Decision> = map.values().collect();
    entries.sort_by_key(|d| (d.fingerprint, d.max_threads));
    let root = Json::obj(vec![
        ("version", Json::Num(CACHE_VERSION)),
        ("decisions", Json::Arr(entries.into_iter().map(decision_to_json).collect())),
    ]);
    // Atomic write: a half-written file would read back as "corrupt →
    // empty" and silently re-tune everything on the next start.
    crate::util::write_atomic(path, &root.dump())
}

fn parse_features(j: &Json) -> Option<Features> {
    Some(Features {
        n: j.get("n")?.as_usize()?,
        work_flops: j.get("work_flops")?.as_usize()?,
        scatter_pairs: j.get("scatter_pairs")?.as_usize()?,
        scatter_ratio: j.get("scatter_ratio")?.as_f64()?,
        bandwidth: j.get("bandwidth")?.as_usize()?,
        // Window features were added with the windowed-buffers change;
        // entries written before it load with neutral values.
        window_rows: j.get("window_rows").and_then(Json::as_usize).unwrap_or(0),
        window_shrink: j.get("window_shrink").and_then(Json::as_f64).unwrap_or(1.0),
        colors: j.get("colors")?.as_usize()?,
        intervals: j.get("intervals")?.as_usize()?,
        balance: j.get("balance")?.as_f64()?,
        nthreads: j.get("feat_nthreads")?.as_usize()?,
    })
}

fn parse_trial(j: &Json) -> Option<TrialResult> {
    Some(TrialResult {
        kind: EngineKind::parse(j.get("kind")?.as_str()?)?,
        // Pre-reorder entries are plain trials.
        reordered: j.get("reordered").and_then(Json::as_bool).unwrap_or(false),
        seconds_per_product: j.get("seconds_per_product")?.as_f64()?,
        mad_s: j.get("mad_s")?.as_f64()?,
        mflops: j.get("mflops")?.as_f64()?,
    })
}

fn parse_sweep_point(j: &Json) -> Option<SweepPoint> {
    Some(SweepPoint {
        nthreads: j.get("nthreads")?.as_usize()?,
        trials: j.get("trials")?.as_arr()?.iter().map(parse_trial).collect::<Option<Vec<_>>>()?,
    })
}

/// One entry; `None` = this record is malformed (the caller skips it).
fn parse_decision(d: &Json) -> Option<((u64, usize), Decision)> {
    let fingerprint = u64::from_str_radix(d.get("fingerprint")?.as_str()?, 16).ok()?;
    let nthreads = d.get("nthreads")?.as_usize()?;
    // v1 entries (no `max_threads`, no `sweep`) load as single-p
    // decisions — backward compatibility is part of the v2 schema.
    let max_threads = d.get("max_threads").and_then(Json::as_usize).unwrap_or(nthreads);
    let sweep = match d.get("sweep") {
        Some(s) => s.as_arr()?.iter().map(parse_sweep_point).collect::<Option<Vec<_>>>()?,
        None => Vec::new(),
    };
    let trials =
        d.get("trials")?.as_arr()?.iter().map(parse_trial).collect::<Option<Vec<_>>>()?;
    let measured = d.get("measured")?.as_bool()?;
    Some((
        (fingerprint, max_threads),
        Decision {
            kind: EngineKind::parse(d.get("kind")?.as_str()?)?,
            // Pre-reorder entries never picked the reordered axis.
            reorder: d.get("reorder").and_then(Json::as_bool).unwrap_or(false),
            mflops: d.get("mflops")?.as_f64()?,
            measured,
            // Entries written before provenance existed: a measured
            // entry came from trials, an unmeasured one from the
            // heuristic (the model postdates the field).
            provenance: d
                .get("provenance")
                .and_then(Json::as_str)
                .and_then(Provenance::parse)
                .unwrap_or(if measured { Provenance::Measured } else { Provenance::Heuristic }),
            served_mflops: d.get("served_mflops").and_then(Json::as_f64).unwrap_or(0.0),
            tuned_s: d.get("tuned_s")?.as_f64()?,
            fingerprint,
            nthreads,
            max_threads,
            features: parse_features(d.get("features")?)?,
            trials,
            sweep,
            // The block axis is additive: entries written before the
            // multi-vector path serve one RHS per product.
            block_k: d.get("block_k").and_then(Json::as_usize).unwrap_or(1),
            block_rates: match d.get("block_rates").and_then(Json::as_arr) {
                Some(arr) => arr
                    .iter()
                    .filter_map(|e| {
                        Some((e.get("k")?.as_usize()?, e.get("mflops")?.as_f64()?))
                    })
                    .collect(),
                None => Vec::new(),
            },
        },
    ))
}

/// Parse a whole cache file. `Err` = the file is unusable (not JSON, no
/// `decisions` array, or a newer schema version); `Ok((map, skipped))`
/// = the healthy entries plus how many malformed ones were dropped.
fn parse_decisions(text: &str) -> Result<(HashMap<(u64, usize), Decision>, usize), String> {
    let j = Json::parse(text).map_err(|e| format!("not valid JSON: {e}"))?;
    if let Some(v) = j.get("version").and_then(Json::as_f64) {
        if v > CACHE_VERSION {
            return Err(format!(
                "schema version {v} is newer than this build understands (max {CACHE_VERSION})"
            ));
        }
    }
    let entries = j
        .get("decisions")
        .and_then(Json::as_arr)
        .ok_or_else(|| "no `decisions` array".to_string())?;
    let mut map = HashMap::new();
    let mut skipped = 0usize;
    for d in entries {
        match parse_decision(d) {
            Some((key, dec)) => {
                map.insert(key, dec);
            }
            None => skipped += 1,
        }
    }
    Ok((map, skipped))
}

/// Read one decision-cache file into a flat, deterministically sorted
/// decision list — the corpus loader's entry point ([`super::model`]).
/// Same per-entry leniency as [`DecisionCache::open`], but file-level
/// problems come back as `Err` so the caller can attribute them.
pub fn load_decisions_file(path: &Path) -> Result<Vec<Decision>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
    let (map, skipped) = parse_decisions(&text)?;
    if skipped > 0 {
        eprintln!(
            "warning: decision cache {}: skipped {skipped} malformed entries",
            path.display()
        );
    }
    let mut v: Vec<Decision> = map.into_values().collect();
    v.sort_by_key(|d| (d.fingerprint, d.max_threads));
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::AccumMethod;

    fn fake_decision(fp: u64, nthreads: usize) -> Decision {
        let trials = vec![TrialResult {
            kind: EngineKind::Colorful,
            reordered: true,
            seconds_per_product: 2.5e-4,
            mad_s: 1e-6,
            mflops: 90.0,
        }];
        Decision {
            kind: EngineKind::LocalBuffers(AccumMethod::Effective),
            reorder: true,
            mflops: 123.5,
            measured: true,
            provenance: Provenance::Measured,
            served_mflops: 0.0,
            tuned_s: 0.01,
            fingerprint: fp,
            nthreads,
            max_threads: nthreads,
            features: Features {
                n: 100,
                work_flops: 900,
                scatter_pairs: 200,
                scatter_ratio: 0.8,
                bandwidth: 17,
                window_rows: 260,
                window_shrink: 0.65,
                colors: 5,
                intervals: 9,
                balance: 1.06,
                nthreads,
            },
            trials: trials.clone(),
            sweep: vec![
                SweepPoint { nthreads: 1, trials: Vec::new() },
                SweepPoint { nthreads, trials },
            ],
            block_k: 4,
            block_rates: vec![(1, 100.0), (2, 110.0), (4, 123.5), (8, 120.0)],
        }
    }

    fn temp_path(tag: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("csrc_decision_cache_{}_{tag}", std::process::id()))
            .join("decisions.json")
    }

    #[test]
    fn persists_and_reloads_decisions() {
        let path = temp_path("roundtrip");
        let _ = std::fs::remove_file(&path);
        let cache = DecisionCache::open(&path);
        assert!(cache.is_empty());
        assert!(cache.get(7, 2).is_none());
        cache.put(fake_decision(7, 2));
        cache.put(fake_decision(7, 4)); // same matrix, different threads
        assert_eq!(cache.len(), 2);
        // A fresh instance reads the same file back.
        let back = DecisionCache::open(&path);
        assert_eq!(back.len(), 2);
        let d = back.get(7, 2).expect("persisted decision");
        assert_eq!(d.kind, EngineKind::LocalBuffers(AccumMethod::Effective));
        assert!(d.measured);
        // The reorder axis and window features round-trip.
        assert!(d.reorder);
        assert!(d.trials[0].reordered);
        assert_eq!(d.features.window_rows, 260);
        assert!((d.features.window_shrink - 0.65).abs() < 1e-12);
        assert_eq!(d.features.colors, 5);
        assert_eq!(d.trials.len(), 1);
        assert_eq!(d.trials[0].kind, EngineKind::Colorful);
        assert!((d.trials[0].seconds_per_product - 2.5e-4).abs() < 1e-12);
        // The v2 surface round-trips: key threads and the sweep rungs.
        assert_eq!(d.max_threads, 2);
        assert_eq!(d.sweep.len(), 2);
        assert_eq!(d.sweep[0].nthreads, 1);
        assert!(d.sweep[0].trials.is_empty());
        assert_eq!(d.sweep[1].nthreads, 2);
        assert_eq!(d.sweep[1].trials[0].kind, EngineKind::Colorful);
        assert_eq!(back.hits(), 1);
        assert_eq!(back.misses(), 0);
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn v1_files_load_as_single_p_decisions() {
        // A hand-rolled v1 file: no `max_threads`, no `sweep` — exactly
        // what the pre-sweep serializer wrote. It must load without
        // error, keyed (fingerprint × nthreads), with an empty sweep
        // surface (the "upgrade me" marker for sweeping callers).
        let text = r#"{
            "version": 1,
            "decisions": [{
                "fingerprint": "000000000000002a",
                "nthreads": 3,
                "kind": "colorful",
                "mflops": 55.5,
                "measured": true,
                "tuned_s": 0.02,
                "features": {
                    "n": 64, "work_flops": 500, "scatter_pairs": 100,
                    "scatter_ratio": 0.7, "bandwidth": 9, "colors": 3,
                    "intervals": 5, "balance": 1.01, "feat_nthreads": 3
                },
                "trials": [{
                    "kind": "colorful", "seconds_per_product": 1.0e-4,
                    "mad_s": 1.0e-6, "mflops": 55.5
                }]
            }]
        }"#;
        let path = temp_path("v1compat");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, text).unwrap();
        let cache = DecisionCache::open(&path);
        assert_eq!(cache.len(), 1, "v1 decision files must load without error");
        let d = cache.get(0x2a, 3).expect("v1 entry keyed by its nthreads");
        assert_eq!(d.kind, EngineKind::Colorful);
        assert_eq!(d.nthreads, 3);
        assert!(!d.reorder, "pre-reorder entries load as plain decisions");
        assert!(!d.trials[0].reordered);
        assert!((d.features.window_shrink - 1.0).abs() < 1e-12);
        assert_eq!(d.max_threads, 3, "v1 entries are single-p: budget == pick");
        assert!(d.sweep.is_empty());
        // Re-writing the file upgrades it to the v2 schema.
        cache.put(fake_decision(9, 2));
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"version\":2"), "{text}");
        assert!(text.contains("\"max_threads\""));
        let back = DecisionCache::open(&path);
        assert_eq!(back.len(), 2);
        assert!(back.get(0x2a, 3).is_some(), "v1 entry survives the rewrite");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn corrupt_file_starts_empty() {
        let path = temp_path("corrupt");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, "{ not json").unwrap();
        let cache = DecisionCache::open(&path);
        assert!(cache.is_empty());
        // And put() repairs the file.
        cache.put(fake_decision(1, 2));
        assert_eq!(DecisionCache::open(&path).len(), 1);
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn truncated_file_starts_empty_and_recovers() {
        // Write a healthy cache, then chop the file mid-entry — the
        // shape a crash mid-copy or a half-synced disk produces. The
        // cache must open empty (no abort, no panic) and be writable.
        let path = temp_path("truncated");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
        {
            let cache = DecisionCache::open(&path);
            cache.put(fake_decision(11, 2));
            cache.put(fake_decision(12, 2));
        }
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() / 2]).unwrap();
        let cache = DecisionCache::open(&path);
        assert!(cache.is_empty(), "truncated JSON must degrade to an empty cache");
        cache.put(fake_decision(13, 2));
        assert_eq!(DecisionCache::open(&path).len(), 1, "put() repairs the file");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn newer_schema_version_is_ignored_wholesale() {
        // A file stamped by a future build may encode entries this one
        // would misread — ignore it (with a warning) instead of
        // guessing.
        let path = temp_path("future");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(
            &path,
            r#"{"version": 99, "decisions": [{"fingerprint": "02a", "nthreads": 2}]}"#,
        )
        .unwrap();
        let cache = DecisionCache::open(&path);
        assert!(cache.is_empty());
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn non_json_bytes_start_empty() {
        let path = temp_path("nonjson");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, b"\x00\x01binary garbage\xff, definitely not json").unwrap();
        assert!(DecisionCache::open(&path).is_empty());
        // A wrong-shape (valid JSON, no `decisions`) file is equally
        // unusable.
        std::fs::write(&path, r#"{"hello": "world"}"#).unwrap();
        assert!(DecisionCache::open(&path).is_empty());
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn malformed_entries_are_skipped_not_fatal() {
        // One bad record in a healthy file must not discard the healthy
        // entries (one corrupt record must not re-tune the fleet).
        let path = temp_path("partial");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
        {
            let cache = DecisionCache::open(&path);
            cache.put(fake_decision(21, 2));
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let sabotaged = text.replace(
            "\"decisions\":[",
            "\"decisions\":[{\"fingerprint\":\"zz-not-hex\",\"nthreads\":1},",
        );
        assert_ne!(sabotaged, text, "sabotage must have landed");
        std::fs::write(&path, sabotaged).unwrap();
        let cache = DecisionCache::open(&path);
        assert_eq!(cache.len(), 1, "the healthy entry survives the malformed one");
        assert!(cache.get(21, 2).is_some());
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn served_rate_and_provenance_round_trip() {
        let path = temp_path("served");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
        let cache = DecisionCache::open(&path);
        cache.put(fake_decision(31, 2));
        // The served-EWMA baseline lands in the entry and the file.
        cache.set_served_rate(31, 2, 77.5);
        cache.set_served_rate(999, 2, 1.0); // unknown key: a no-op
        let back = DecisionCache::open(&path);
        let d = back.get(31, 2).unwrap();
        assert!((d.served_mflops - 77.5).abs() < 1e-12);
        assert_eq!(d.provenance, Provenance::Measured);
        // Clearing persists too (a value swap invalidates the baseline),
        // and an unknown key stays a no-op.
        back.clear_served_rate(31, 2);
        back.clear_served_rate(999, 2);
        let back2 = DecisionCache::open(&path);
        assert_eq!(back2.get(31, 2).unwrap().served_mflops, 0.0);
        back2.set_served_rate(31, 2, 77.5);
        // Pre-provenance files infer it from `measured`.
        let text = std::fs::read_to_string(&path).unwrap();
        let stripped = text
            .replace("\"provenance\":\"measured\",", "")
            .replace("\"served_mflops\":7.75e1,", "");
        std::fs::write(&path, stripped).unwrap();
        let back = DecisionCache::open(&path);
        let d = back.get(31, 2).expect("entry still parses without the new fields");
        assert_eq!(d.provenance, Provenance::Measured, "inferred from measured=true");
        assert_eq!(d.served_mflops, 0.0);
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn block_axis_round_trips_and_defaults_to_one() {
        let path = temp_path("blockk");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
        let cache = DecisionCache::open(&path);
        cache.put(fake_decision(41, 2));
        let back = DecisionCache::open(&path);
        let d = back.get(41, 2).unwrap();
        assert_eq!(d.block_k, 4);
        assert_eq!(d.block_rates.len(), 4);
        assert_eq!(d.block_rates[2], (4, 123.5));
        // The persisted file names the new fields.
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"block_k\""), "{text}");
        assert!(text.contains("\"block_rates\""), "{text}");
        // Entries written before the block axis existed load with
        // block_k = 1 and no rate curve — additive schema, same rule as
        // provenance/served_mflops.
        let pre_block = r#"{
            "version": 2,
            "decisions": [{
                "fingerprint": "0000000000000029",
                "nthreads": 2,
                "max_threads": 2,
                "kind": "colorful",
                "mflops": 55.5,
                "measured": true,
                "tuned_s": 0.02,
                "features": {
                    "n": 64, "work_flops": 500, "scatter_pairs": 100,
                    "scatter_ratio": 0.7, "bandwidth": 9, "colors": 3,
                    "intervals": 5, "balance": 1.01, "feat_nthreads": 2
                },
                "trials": [{
                    "kind": "colorful", "seconds_per_product": 1.0e-4,
                    "mad_s": 1.0e-6, "mflops": 55.5
                }]
            }]
        }"#;
        std::fs::write(&path, pre_block).unwrap();
        let back = DecisionCache::open(&path);
        let d = back.get(0x29, 2).expect("entry parses without the block fields");
        assert_eq!(d.block_k, 1);
        assert!(d.block_rates.is_empty());
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn in_memory_counts_hits_and_misses() {
        let cache = DecisionCache::in_memory();
        assert!(cache.get(1, 1).is_none());
        cache.put(fake_decision(1, 1));
        assert!(cache.get(1, 1).is_some());
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
    }
}
