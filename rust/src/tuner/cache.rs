//! JSON-backed persistent decision cache.
//!
//! Keyed by (structure [`super::fingerprint`] × thread *budget*
//! [`Decision::max_threads`]): a restarted service that re-registers a
//! known matrix reads its decision back and performs **zero** new
//! trials. The file is written through on every [`DecisionCache::put`];
//! a missing or corrupt file starts the cache empty rather than failing
//! — persisted decisions are a performance artifact, not a source of
//! truth.
//!
//! **Schema v2** (current): entries carry `max_threads` (the cache key's
//! second half) and the full `sweep` surface of [`super::SweepPoint`]s.
//! v1 files — written before the thread sweep existed — load without
//! error as single-p decisions: `max_threads` defaults to the entry's
//! `nthreads` and the sweep surface is empty, which is exactly what
//! [`super::resolve_swept`] treats as "upgrade me when a sweeping caller
//! brings a measuring budget".

use super::{Decision, Features, SweepPoint, TrialResult};
use crate::parallel::EngineKind;
use crate::util::json::Json;
use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

pub struct DecisionCache {
    path: Option<PathBuf>,
    map: Mutex<HashMap<(u64, usize), Decision>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl DecisionCache {
    /// Session-local cache with no backing file.
    pub fn in_memory() -> DecisionCache {
        DecisionCache {
            path: None,
            map: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Open (or create on first `put`) a persistent cache at `path`.
    pub fn open(path: &Path) -> DecisionCache {
        let map = std::fs::read_to_string(path)
            .ok()
            .and_then(|text| parse_decisions(&text))
            .unwrap_or_default();
        DecisionCache {
            path: Some(path.to_path_buf()),
            map: Mutex::new(map),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Look up by (fingerprint × thread budget). The second component is
    /// [`Decision::max_threads`] — for single-p decisions that equals
    /// the decision's `nthreads`; a swept decision is filed under the
    /// ladder's max even when its winning `nthreads` is smaller.
    pub fn get(&self, fingerprint: u64, max_threads: usize) -> Option<Decision> {
        let got = self.peek(fingerprint, max_threads);
        self.record(got.is_some());
        got
    }

    /// Counter-free lookup for [`super::resolve`], which decides the
    /// hit/miss accounting only after checking whether the entry
    /// actually satisfies the caller's budget (an unmeasured entry a
    /// measuring caller discards must not count as a hit).
    pub(super) fn peek(&self, fingerprint: u64, max_threads: usize) -> Option<Decision> {
        self.map.lock().unwrap().get(&(fingerprint, max_threads)).cloned()
    }

    pub(super) fn record(&self, hit: bool) {
        if hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Insert a decision and (when persistent) write the file through.
    /// Disk errors are swallowed: the in-memory cache stays authoritative
    /// for this process either way.
    pub fn put(&self, d: Decision) {
        let mut map = self.map.lock().unwrap();
        map.insert((d.fingerprint, d.max_threads), d);
        if let Some(path) = &self.path {
            let _ = write_decisions(path, &map);
        }
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn features_to_json(f: &Features) -> Json {
    obj(vec![
        ("n", Json::Num(f.n as f64)),
        ("work_flops", Json::Num(f.work_flops as f64)),
        ("scatter_pairs", Json::Num(f.scatter_pairs as f64)),
        ("scatter_ratio", Json::Num(f.scatter_ratio)),
        ("bandwidth", Json::Num(f.bandwidth as f64)),
        ("window_rows", Json::Num(f.window_rows as f64)),
        ("window_shrink", Json::Num(f.window_shrink)),
        ("colors", Json::Num(f.colors as f64)),
        ("intervals", Json::Num(f.intervals as f64)),
        ("balance", Json::Num(f.balance)),
        ("feat_nthreads", Json::Num(f.nthreads as f64)),
    ])
}

fn trial_to_json(t: &TrialResult) -> Json {
    obj(vec![
        ("kind", Json::Str(t.kind.label())),
        ("reordered", Json::Bool(t.reordered)),
        ("seconds_per_product", Json::Num(t.seconds_per_product)),
        ("mad_s", Json::Num(t.mad_s)),
        ("mflops", Json::Num(t.mflops)),
    ])
}

fn sweep_point_to_json(pt: &SweepPoint) -> Json {
    obj(vec![
        ("nthreads", Json::Num(pt.nthreads as f64)),
        ("trials", Json::Arr(pt.trials.iter().map(trial_to_json).collect())),
    ])
}

fn decision_to_json(d: &Decision) -> Json {
    obj(vec![
        ("fingerprint", Json::Str(format!("{:016x}", d.fingerprint))),
        ("nthreads", Json::Num(d.nthreads as f64)),
        ("max_threads", Json::Num(d.max_threads as f64)),
        ("kind", Json::Str(d.kind.label())),
        ("reorder", Json::Bool(d.reorder)),
        ("mflops", Json::Num(d.mflops)),
        ("measured", Json::Bool(d.measured)),
        ("tuned_s", Json::Num(d.tuned_s)),
        ("features", features_to_json(&d.features)),
        ("trials", Json::Arr(d.trials.iter().map(trial_to_json).collect())),
        ("sweep", Json::Arr(d.sweep.iter().map(sweep_point_to_json).collect())),
    ])
}

/// JSON form of one decision — the persisted v2 schema's entry shape,
/// exposed for CLI sweep reports (`csrc tune --report`).
pub fn decision_json(d: &Decision) -> Json {
    decision_to_json(d)
}

fn write_decisions(path: &Path, map: &HashMap<(u64, usize), Decision>) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut entries: Vec<&Decision> = map.values().collect();
    entries.sort_by_key(|d| (d.fingerprint, d.max_threads));
    let root = obj(vec![
        ("version", Json::Num(2.0)),
        ("decisions", Json::Arr(entries.into_iter().map(decision_to_json).collect())),
    ]);
    // Write-to-temp + rename so a crash mid-write cannot truncate the
    // cache (a half-written file would read back as "corrupt → empty"
    // and silently re-tune everything on the next start).
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, root.dump())?;
    std::fs::rename(&tmp, path)
}

fn parse_features(j: &Json) -> Option<Features> {
    Some(Features {
        n: j.get("n")?.as_usize()?,
        work_flops: j.get("work_flops")?.as_usize()?,
        scatter_pairs: j.get("scatter_pairs")?.as_usize()?,
        scatter_ratio: j.get("scatter_ratio")?.as_f64()?,
        bandwidth: j.get("bandwidth")?.as_usize()?,
        // Window features were added with the windowed-buffers change;
        // entries written before it load with neutral values.
        window_rows: j.get("window_rows").and_then(Json::as_usize).unwrap_or(0),
        window_shrink: j.get("window_shrink").and_then(Json::as_f64).unwrap_or(1.0),
        colors: j.get("colors")?.as_usize()?,
        intervals: j.get("intervals")?.as_usize()?,
        balance: j.get("balance")?.as_f64()?,
        nthreads: j.get("feat_nthreads")?.as_usize()?,
    })
}

fn parse_trial(j: &Json) -> Option<TrialResult> {
    Some(TrialResult {
        kind: EngineKind::parse(j.get("kind")?.as_str()?)?,
        // Pre-reorder entries are plain trials.
        reordered: j.get("reordered").and_then(Json::as_bool).unwrap_or(false),
        seconds_per_product: j.get("seconds_per_product")?.as_f64()?,
        mad_s: j.get("mad_s")?.as_f64()?,
        mflops: j.get("mflops")?.as_f64()?,
    })
}

fn parse_sweep_point(j: &Json) -> Option<SweepPoint> {
    Some(SweepPoint {
        nthreads: j.get("nthreads")?.as_usize()?,
        trials: j.get("trials")?.as_arr()?.iter().map(parse_trial).collect::<Option<Vec<_>>>()?,
    })
}

fn parse_decisions(text: &str) -> Option<HashMap<(u64, usize), Decision>> {
    let j = Json::parse(text).ok()?;
    let mut map = HashMap::new();
    for d in j.get("decisions")?.as_arr()? {
        let fingerprint = u64::from_str_radix(d.get("fingerprint")?.as_str()?, 16).ok()?;
        let nthreads = d.get("nthreads")?.as_usize()?;
        // v1 entries (no `max_threads`, no `sweep`) load as single-p
        // decisions — backward compatibility is part of the v2 schema.
        let max_threads = d.get("max_threads").and_then(Json::as_usize).unwrap_or(nthreads);
        let sweep = match d.get("sweep") {
            Some(s) => s.as_arr()?.iter().map(parse_sweep_point).collect::<Option<Vec<_>>>()?,
            None => Vec::new(),
        };
        let trials = d
            .get("trials")?
            .as_arr()?
            .iter()
            .map(parse_trial)
            .collect::<Option<Vec<_>>>()?;
        map.insert(
            (fingerprint, max_threads),
            Decision {
                kind: EngineKind::parse(d.get("kind")?.as_str()?)?,
                // Pre-reorder entries never picked the reordered axis.
                reorder: d.get("reorder").and_then(Json::as_bool).unwrap_or(false),
                mflops: d.get("mflops")?.as_f64()?,
                measured: d.get("measured")?.as_bool()?,
                tuned_s: d.get("tuned_s")?.as_f64()?,
                fingerprint,
                nthreads,
                max_threads,
                features: parse_features(d.get("features")?)?,
                trials,
                sweep,
            },
        );
    }
    Some(map)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::AccumMethod;

    fn fake_decision(fp: u64, nthreads: usize) -> Decision {
        let trials = vec![TrialResult {
            kind: EngineKind::Colorful,
            reordered: true,
            seconds_per_product: 2.5e-4,
            mad_s: 1e-6,
            mflops: 90.0,
        }];
        Decision {
            kind: EngineKind::LocalBuffers(AccumMethod::Effective),
            reorder: true,
            mflops: 123.5,
            measured: true,
            tuned_s: 0.01,
            fingerprint: fp,
            nthreads,
            max_threads: nthreads,
            features: Features {
                n: 100,
                work_flops: 900,
                scatter_pairs: 200,
                scatter_ratio: 0.8,
                bandwidth: 17,
                window_rows: 260,
                window_shrink: 0.65,
                colors: 5,
                intervals: 9,
                balance: 1.06,
                nthreads,
            },
            trials: trials.clone(),
            sweep: vec![
                SweepPoint { nthreads: 1, trials: Vec::new() },
                SweepPoint { nthreads, trials },
            ],
        }
    }

    fn temp_path(tag: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("csrc_decision_cache_{}_{tag}", std::process::id()))
            .join("decisions.json")
    }

    #[test]
    fn persists_and_reloads_decisions() {
        let path = temp_path("roundtrip");
        let _ = std::fs::remove_file(&path);
        let cache = DecisionCache::open(&path);
        assert!(cache.is_empty());
        assert!(cache.get(7, 2).is_none());
        cache.put(fake_decision(7, 2));
        cache.put(fake_decision(7, 4)); // same matrix, different threads
        assert_eq!(cache.len(), 2);
        // A fresh instance reads the same file back.
        let back = DecisionCache::open(&path);
        assert_eq!(back.len(), 2);
        let d = back.get(7, 2).expect("persisted decision");
        assert_eq!(d.kind, EngineKind::LocalBuffers(AccumMethod::Effective));
        assert!(d.measured);
        // The reorder axis and window features round-trip.
        assert!(d.reorder);
        assert!(d.trials[0].reordered);
        assert_eq!(d.features.window_rows, 260);
        assert!((d.features.window_shrink - 0.65).abs() < 1e-12);
        assert_eq!(d.features.colors, 5);
        assert_eq!(d.trials.len(), 1);
        assert_eq!(d.trials[0].kind, EngineKind::Colorful);
        assert!((d.trials[0].seconds_per_product - 2.5e-4).abs() < 1e-12);
        // The v2 surface round-trips: key threads and the sweep rungs.
        assert_eq!(d.max_threads, 2);
        assert_eq!(d.sweep.len(), 2);
        assert_eq!(d.sweep[0].nthreads, 1);
        assert!(d.sweep[0].trials.is_empty());
        assert_eq!(d.sweep[1].nthreads, 2);
        assert_eq!(d.sweep[1].trials[0].kind, EngineKind::Colorful);
        assert_eq!(back.hits(), 1);
        assert_eq!(back.misses(), 0);
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn v1_files_load_as_single_p_decisions() {
        // A hand-rolled v1 file: no `max_threads`, no `sweep` — exactly
        // what the pre-sweep serializer wrote. It must load without
        // error, keyed (fingerprint × nthreads), with an empty sweep
        // surface (the "upgrade me" marker for sweeping callers).
        let text = r#"{
            "version": 1,
            "decisions": [{
                "fingerprint": "000000000000002a",
                "nthreads": 3,
                "kind": "colorful",
                "mflops": 55.5,
                "measured": true,
                "tuned_s": 0.02,
                "features": {
                    "n": 64, "work_flops": 500, "scatter_pairs": 100,
                    "scatter_ratio": 0.7, "bandwidth": 9, "colors": 3,
                    "intervals": 5, "balance": 1.01, "feat_nthreads": 3
                },
                "trials": [{
                    "kind": "colorful", "seconds_per_product": 1.0e-4,
                    "mad_s": 1.0e-6, "mflops": 55.5
                }]
            }]
        }"#;
        let path = temp_path("v1compat");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, text).unwrap();
        let cache = DecisionCache::open(&path);
        assert_eq!(cache.len(), 1, "v1 decision files must load without error");
        let d = cache.get(0x2a, 3).expect("v1 entry keyed by its nthreads");
        assert_eq!(d.kind, EngineKind::Colorful);
        assert_eq!(d.nthreads, 3);
        assert!(!d.reorder, "pre-reorder entries load as plain decisions");
        assert!(!d.trials[0].reordered);
        assert!((d.features.window_shrink - 1.0).abs() < 1e-12);
        assert_eq!(d.max_threads, 3, "v1 entries are single-p: budget == pick");
        assert!(d.sweep.is_empty());
        // Re-writing the file upgrades it to the v2 schema.
        cache.put(fake_decision(9, 2));
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"version\":2"), "{text}");
        assert!(text.contains("\"max_threads\""));
        let back = DecisionCache::open(&path);
        assert_eq!(back.len(), 2);
        assert!(back.get(0x2a, 3).is_some(), "v1 entry survives the rewrite");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn corrupt_file_starts_empty() {
        let path = temp_path("corrupt");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, "{ not json").unwrap();
        let cache = DecisionCache::open(&path);
        assert!(cache.is_empty());
        // And put() repairs the file.
        cache.put(fake_decision(1, 2));
        assert_eq!(DecisionCache::open(&path).len(), 1);
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn in_memory_counts_hits_and_misses() {
        let cache = DecisionCache::in_memory();
        assert!(cache.get(1, 1).is_none());
        cache.put(fake_decision(1, 1));
        assert!(cache.get(1, 1).is_some());
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
    }
}
