//! Learned cross-matrix cost model — the zero-budget/cold-start path.
//!
//! The hand-written [`super::cost_model`] heuristic encodes the paper's
//! §4 conclusions as fixed thresholds; RACE (Alappat et al., 2019) and
//! Schubert et al. (2009) show the winning symmetric-SpMV strategy is
//! *predictable* from structural features, and since the sweep/reorder
//! PRs every measured decision persists exactly those features into the
//! decision cache. This module closes the loop:
//!
//! 1. [`load_corpus`] / [`rows_from_decisions`] flatten one or more
//!    decision-cache files (schema v1 and v2) into labeled
//!    [`CorpusRow`]s;
//! 2. [`CostModel::train`] fits a per-class regularized softmax scorer
//!    over the normalized [`Features`] vector (the engine × ordering
//!    pick) plus one ridge rate-regressor per thread-ladder rung (the
//!    thread pick) — dependency-free, deterministic (same corpus ⇒
//!    byte-identical model file);
//! 3. [`CostModel::predict`] / [`CostModel::predict_threads`] answer
//!    for never-before-seen matrices; the resolvers
//!    ([`super::resolve_with_model`], [`super::resolve_swept_with_model`])
//!    and `MatvecService::register` consult them *before* falling back
//!    to the heuristic, and the pick's provenance travels in
//!    [`super::Decision::provenance`].
//!
//! Fallback order everywhere: decision-cache hit → model prediction →
//! hand-written heuristic. A model prediction is still a placeholder —
//! it is persisted unmeasured, so any caller with a measuring budget
//! upgrades it with real trials.

mod corpus;
mod train;

pub use corpus::{load_corpus, rows_from_decisions, CorpusRow};

use super::Features;
use crate::parallel::EngineKind;
use crate::reorder::ReorderPolicy;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// Model file schema version.
const MODEL_VERSION: f64 = 1.0;

/// One class of the (engine × ordering) label space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClassLabel {
    pub kind: EngineKind,
    pub reordered: bool,
}

impl ClassLabel {
    /// Same spelling as [`super::Decision::label`]: the engine kind,
    /// `reordered/`-prefixed when the class executes through RCM.
    pub fn label(&self) -> String {
        if self.reordered {
            format!("reordered/{}", self.kind.label())
        } else {
            self.kind.label()
        }
    }

    pub fn parse(s: &str) -> Option<ClassLabel> {
        let (body, reordered) = match s.strip_prefix("reordered/") {
            Some(rest) => (rest, true),
            None => (s, false),
        };
        let kind = EngineKind::parse(body)?;
        if kind == EngineKind::Auto {
            return None; // a selector, never a measured winner
        }
        Some(ClassLabel { kind, reordered })
    }
}

/// What the model concludes for one feature vector.
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    pub kind: EngineKind,
    /// Execute through the RCM ordering.
    pub reordered: bool,
    /// Softmax probability of the winning class — a confidence signal
    /// for logs, not a calibrated probability.
    pub confidence: f64,
}

/// The trained model: feature standardization + per-class softmax
/// weights + per-rung rate regressors.
#[derive(Clone, Debug)]
pub struct CostModel {
    mean: Vec<f64>,
    std: Vec<f64>,
    /// Sorted by label — the deterministic class order the weight rows
    /// follow.
    classes: Vec<ClassLabel>,
    /// Per-class weights over standardized features + trailing bias.
    weights: Vec<Vec<f64>>,
    /// Per thread-count regressors (sorted by p): predict
    /// `ln(1 + best Mflop/s at p)` from the standardized features.
    rungs: Vec<(usize, Vec<f64>)>,
    /// Per block-width regressors (sorted by k): predict
    /// `ln(1 + per-vector Mflop/s at width k)` from the standardized
    /// features. Empty when the corpus predates the block axis.
    kblocks: Vec<(usize, Vec<f64>)>,
    /// Rows the model was trained on (provenance for reports).
    trained_rows: usize,
}

fn standardize(raw: &[f64], mean: &[f64], std: &[f64]) -> Vec<f64> {
    let mut v: Vec<f64> =
        raw.iter().zip(mean.iter().zip(std)).map(|(x, (m, s))| (x - m) / s).collect();
    v.push(1.0); // bias
    v
}

impl CostModel {
    /// Fit the model on a (sorted — [`rows_from_decisions`] guarantees
    /// it) corpus. `None` on an empty corpus; a single-class corpus is
    /// legal and yields a constant predictor.
    pub fn train(rows: &[CorpusRow]) -> Option<CostModel> {
        if rows.is_empty() {
            return None;
        }
        let nraw = Features::RAW_FEATURE_NAMES.len();
        let raw: Vec<[f64; 10]> = rows.iter().map(|r| r.features.raw_vector()).collect();
        let mut mean = vec![0.0; nraw];
        for x in &raw {
            for (m, v) in mean.iter_mut().zip(x) {
                *m += v;
            }
        }
        for m in mean.iter_mut() {
            *m /= raw.len() as f64;
        }
        let mut std = vec![0.0; nraw];
        for x in &raw {
            for (s, (v, m)) in std.iter_mut().zip(x.iter().zip(&mean)) {
                let d = v - m;
                *s += d * d;
            }
        }
        for s in std.iter_mut() {
            *s = (*s / raw.len() as f64).sqrt();
            if *s < 1e-9 {
                *s = 1.0; // constant column: center to 0, don't divide by ~0
            }
        }
        let xs: Vec<Vec<f64>> = raw.iter().map(|x| standardize(x, &mean, &std)).collect();
        let mut classes: Vec<ClassLabel> = Vec::new();
        for r in rows {
            let c = ClassLabel { kind: r.kind, reordered: r.reordered };
            if !classes.contains(&c) {
                classes.push(c);
            }
        }
        classes.sort_by_key(|c| c.label());
        let y: Vec<usize> = rows
            .iter()
            .map(|r| {
                let c = ClassLabel { kind: r.kind, reordered: r.reordered };
                classes.iter().position(|k| *k == c).expect("class was recorded above")
            })
            .collect();
        let weights = train::fit_softmax(&xs, &y, classes.len());
        // Rung regressors over whatever sweep surfaces the corpus holds
        // (BTreeMap: deterministic ascending-p order). Rung 1 is
        // skipped: `predict_threads` never selects it — sequential
        // always runs at one thread and parallel picks start at 2 — so
        // fitting it would only put dead weights in every model file.
        let mut by_p: BTreeMap<usize, (Vec<Vec<f64>>, Vec<f64>)> = BTreeMap::new();
        for (row, x) in rows.iter().zip(&xs) {
            for &(p, rate) in &row.rung_rates {
                if p >= 2 && rate > 0.0 && rate.is_finite() {
                    let e = by_p.entry(p).or_default();
                    e.0.push(x.clone());
                    e.1.push((1.0 + rate).ln());
                }
            }
        }
        let rungs: Vec<(usize, Vec<f64>)> =
            by_p.into_iter().map(|(p, (x, y))| (p, train::fit_ridge(&x, &y))).collect();
        // Block-width regressors over whatever block axes the corpus
        // holds. Width 1 is kept — unlike rung 1 it is a real candidate
        // (narrow matrices lose to panel overhead), so the argmax in
        // `predict_block_k` needs its rate on the same scale.
        let mut by_k: BTreeMap<usize, (Vec<Vec<f64>>, Vec<f64>)> = BTreeMap::new();
        for (row, x) in rows.iter().zip(&xs) {
            for &(k, rate) in &row.block_rates {
                if rate > 0.0 && rate.is_finite() {
                    let e = by_k.entry(k).or_default();
                    e.0.push(x.clone());
                    e.1.push((1.0 + rate).ln());
                }
            }
        }
        let kblocks: Vec<(usize, Vec<f64>)> =
            by_k.into_iter().map(|(k, (x, y))| (k, train::fit_ridge(&x, &y))).collect();
        Some(CostModel { mean, std, classes, weights, rungs, kblocks, trained_rows: rows.len() })
    }

    /// Score every class compatible with `policy` and return the
    /// argmax. `Never` restricts to plain classes (reordered execution
    /// is an opt-in); `Always` forces the flag on whatever wins, the
    /// same rule the heuristic path uses. `None` only when no class is
    /// compatible (e.g. a reordered-only model asked under `Never`).
    pub fn predict(&self, f: &Features, policy: ReorderPolicy) -> Option<Prediction> {
        let x = standardize(&f.raw_vector(), &self.mean, &self.std);
        let mut scores: Vec<f64> = self.weights.iter().map(|w| train::dot(w, &x)).collect();
        train::softmax_in_place(&mut scores);
        let (best, conf) = self
            .classes
            .iter()
            .zip(&scores)
            .filter(|(c, _)| policy != ReorderPolicy::Never || !c.reordered)
            .max_by(|a, b| a.1.partial_cmp(b.1).expect("softmax is finite"))
            .map(|(c, s)| (*c, *s))?;
        Some(Prediction {
            kind: best.kind,
            reordered: best.reordered || policy == ReorderPolicy::Always,
            confidence: conf,
        })
    }

    /// Thread pick for a predicted engine: evaluate the trained rate
    /// regressors at every rung in `2..=max` and take the argmax.
    /// Sequential always runs at one thread; without any applicable
    /// rung the parallel pick falls back to the full budget — the same
    /// rule the heuristic path uses.
    pub fn predict_threads(&self, f: &Features, kind: EngineKind, max: usize) -> usize {
        let max = max.max(1);
        if kind == EngineKind::Sequential {
            return 1;
        }
        let x = standardize(&f.raw_vector(), &self.mean, &self.std);
        let best = self
            .rungs
            .iter()
            .filter(|(p, _)| *p >= 2 && *p <= max)
            .map(|(p, w)| (*p, train::dot(w, &x)))
            .max_by(|a, b| a.1.partial_cmp(&b.1).expect("rates are finite"));
        best.map_or(max, |(p, _)| p)
    }

    /// Block-width pick for multi-RHS work: evaluate the trained
    /// per-width rate regressors (per-vector Mflop/s) at every width
    /// `<= max_k` and take the argmax. A corpus that predates the block
    /// axis trains no width regressors; the pick then falls back to the
    /// structural heuristic — the same answer a model-less zero-budget
    /// caller gets.
    pub fn predict_block_k(&self, f: &Features, max_k: usize) -> usize {
        let max_k = max_k.max(1);
        if self.kblocks.is_empty() {
            return super::heuristic_block_k(f).min(max_k);
        }
        let x = standardize(&f.raw_vector(), &self.mean, &self.std);
        self.kblocks
            .iter()
            .filter(|(k, _)| *k <= max_k)
            .map(|(k, w)| (*k, train::dot(w, &x)))
            .max_by(|a, b| a.1.partial_cmp(&b.1).expect("rates are finite"))
            .map_or(1, |(k, _)| k)
    }

    /// Short human summary for CLI/stat lines.
    pub fn summary(&self) -> String {
        format!(
            "{} classes, {} thread rungs, {} block widths, trained on {} decisions",
            self.classes.len(),
            self.rungs.len(),
            self.kblocks.len(),
            self.trained_rows
        )
    }

    pub fn trained_rows(&self) -> usize {
        self.trained_rows
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::Num(MODEL_VERSION)),
            (
                "feature_names",
                Json::Arr(
                    Features::RAW_FEATURE_NAMES
                        .iter()
                        .map(|s| Json::Str(s.to_string()))
                        .collect(),
                ),
            ),
            ("mean", jnums(&self.mean)),
            ("std", jnums(&self.std)),
            (
                "classes",
                Json::Arr(self.classes.iter().map(|c| Json::Str(c.label())).collect()),
            ),
            ("weights", Json::Arr(self.weights.iter().map(|w| jnums(w)).collect())),
            (
                "rungs",
                Json::Arr(
                    self.rungs
                        .iter()
                        .map(|(p, w)| {
                            Json::obj(vec![
                                ("nthreads", Json::Num(*p as f64)),
                                ("weights", jnums(w)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "kblocks",
                Json::Arr(
                    self.kblocks
                        .iter()
                        .map(|(k, w)| {
                            Json::obj(vec![
                                ("k", Json::Num(*k as f64)),
                                ("weights", jnums(w)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("trained_rows", Json::Num(self.trained_rows as f64)),
        ])
    }

    /// `None` when the value is not a model file this build understands
    /// (wrong shape, non-finite numbers, unknown class label, or a
    /// *newer* schema version). Values are validated, not just shapes:
    /// a hand-edited `1e999` parses as `inf` and would otherwise
    /// surface as a NaN-softmax panic deep inside `predict` — the exact
    /// config typo [`CostModel::load`] promises to degrade past.
    pub fn from_json(j: &Json) -> Option<CostModel> {
        if j.get("version")?.as_f64()? > MODEL_VERSION {
            return None;
        }
        // The persisted feature names must match this build's layout
        // exactly: a model trained under a different `raw_vector`
        // ordering would load cleanly by shape and then multiply every
        // weight by the wrong feature.
        let names: Vec<&str> = j
            .get("feature_names")?
            .as_arr()?
            .iter()
            .map(Json::as_str)
            .collect::<Option<Vec<_>>>()?;
        if names != Features::RAW_FEATURE_NAMES {
            return None;
        }
        let mean = jnums_back(j.get("mean")?)?;
        let std = jnums_back(j.get("std")?)?;
        let nraw = Features::RAW_FEATURE_NAMES.len();
        if mean.len() != nraw || std.len() != nraw {
            return None;
        }
        if !all_finite(&mean) || !all_finite(&std) || std.iter().any(|s| *s <= 0.0) {
            return None;
        }
        let classes: Vec<ClassLabel> = j
            .get("classes")?
            .as_arr()?
            .iter()
            .map(|c| ClassLabel::parse(c.as_str()?))
            .collect::<Option<Vec<_>>>()?;
        if classes.is_empty() {
            return None;
        }
        let weights: Vec<Vec<f64>> = j
            .get("weights")?
            .as_arr()?
            .iter()
            .map(jnums_back)
            .collect::<Option<Vec<_>>>()?;
        if weights.len() != classes.len()
            || weights.iter().any(|w| w.len() != nraw + 1 || !all_finite(w))
        {
            return None;
        }
        let rungs: Vec<(usize, Vec<f64>)> = j
            .get("rungs")?
            .as_arr()?
            .iter()
            .map(|r| {
                let p = r.get("nthreads")?.as_usize()?;
                let w = jnums_back(r.get("weights")?)?;
                if w.len() != nraw + 1 || !all_finite(&w) {
                    return None;
                }
                Some((p, w))
            })
            .collect::<Option<Vec<_>>>()?;
        // Additive: model files written before the block axis have no
        // `kblocks` and load with none (predict_block_k then falls back
        // to the heuristic). A *present* but malformed array is a bad
        // file, rejected like any other shape error.
        let kblocks: Vec<(usize, Vec<f64>)> = match j.get("kblocks") {
            None => Vec::new(),
            Some(arr) => arr
                .as_arr()?
                .iter()
                .map(|r| {
                    let k = r.get("k")?.as_usize()?;
                    let w = jnums_back(r.get("weights")?)?;
                    if w.len() != nraw + 1 || !all_finite(&w) {
                        return None;
                    }
                    Some((k, w))
                })
                .collect::<Option<Vec<_>>>()?,
        };
        let trained_rows = j.get("trained_rows").and_then(Json::as_usize).unwrap_or(0);
        Some(CostModel { mean, std, classes, weights, rungs, kblocks, trained_rows })
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        // Atomic write: a truncated model file would make every later
        // `--model` caller silently degrade to the heuristic.
        crate::util::write_atomic(path, &self.to_json().dump())
    }

    /// Read a model file; `None` — with a warning — when the file is
    /// missing or is not a valid model, so callers degrade to the
    /// heuristic instead of dying on a config typo.
    pub fn load(path: &Path) -> Option<CostModel> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!(
                    "warning: cost model {} unreadable ({e}); falling back to the heuristic",
                    path.display()
                );
                return None;
            }
        };
        let parsed = Json::parse(&text).ok().as_ref().and_then(CostModel::from_json);
        if parsed.is_none() {
            eprintln!(
                "warning: cost model {} is not a valid model file; falling back to the heuristic",
                path.display()
            );
        }
        parsed
    }
}

fn jnums(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
}

fn jnums_back(j: &Json) -> Option<Vec<f64>> {
    j.as_arr()?.iter().map(Json::as_f64).collect()
}

fn all_finite(v: &[f64]) -> bool {
    v.iter().all(|x| x.is_finite())
}

#[cfg(test)]
mod tests {
    use super::super::cost_model;
    use super::*;
    use crate::parallel::AccumMethod;

    fn feat(n: usize, scatter_ratio: f64, colors: usize, intervals: usize, p: usize) -> Features {
        Features {
            n,
            work_flops: 9 * n,
            scatter_pairs: (scatter_ratio * n as f64) as usize,
            scatter_ratio,
            bandwidth: n / 10,
            window_rows: 2 * n,
            window_shrink: (2.0 / p as f64).min(1.0),
            colors,
            intervals,
            balance: 1.05,
            nthreads: p,
        }
    }

    /// Planted rule: heavy scattering ⇒ colorful wins, light ⇒ interval
    /// accumulation. Deliberately *not* what `cost_model` says for these
    /// features (colors = 8 ⇒ it never picks colorful; intervals = 8 ≤
    /// 4·p ⇒ it picks effective), so only a model that actually learned
    /// the corpus can match the recorded winners.
    fn planted_row(i: usize, scatter_ratio: f64) -> CorpusRow {
        let kind = if scatter_ratio > 0.5 {
            EngineKind::Colorful
        } else {
            EngineKind::LocalBuffers(AccumMethod::Interval)
        };
        CorpusRow {
            fingerprint: i as u64,
            max_threads: 4,
            features: feat(4096 + 64 * i, scatter_ratio, 8, 8, 4),
            kind,
            reordered: false,
            nthreads: 4,
            rung_rates: vec![(1, 400.0), (2, 700.0), (4, 900.0 + i as f64)],
            block_rates: vec![
                (1, 500.0),
                (2, 560.0),
                (4, 640.0 + i as f64),
                (8, 600.0),
            ],
        }
    }

    /// 24 synthetic matrices, scatter ratios well clear of the planted
    /// 0.5 boundary.
    fn planted_corpus() -> Vec<CorpusRow> {
        (0..24)
            .map(|i| {
                let r = if i % 2 == 0 {
                    0.15 + 0.02 * (i / 2) as f64
                } else {
                    0.70 + 0.02 * (i / 2) as f64
                };
                planted_row(i, r)
            })
            .collect()
    }

    #[test]
    fn held_out_picks_beat_the_heuristic_on_a_planted_corpus() {
        // ISSUE 5 acceptance: on a ≥20-matrix synthetic corpus whose
        // measured winner follows a structural rule, the leave-one-out
        // model pick must match the recorded winner strictly more often
        // than the hand-written cost_model does.
        let corpus = planted_corpus();
        assert!(corpus.len() >= 20);
        let mut model_correct = 0usize;
        let mut heuristic_correct = 0usize;
        for i in 0..corpus.len() {
            let held: Vec<CorpusRow> = corpus
                .iter()
                .enumerate()
                .filter(|(j, _)| *j != i)
                .map(|(_, r)| r.clone())
                .collect();
            let m = CostModel::train(&held).expect("trainable corpus");
            let row = &corpus[i];
            let pick = m.predict(&row.features, ReorderPolicy::Never).expect("prediction").kind;
            if pick == row.kind {
                model_correct += 1;
            }
            if cost_model(&row.features) == row.kind {
                heuristic_correct += 1;
            }
        }
        assert!(
            model_correct > heuristic_correct,
            "held-out model picks must beat the heuristic: model {model_correct}/24 \
             vs heuristic {heuristic_correct}/24"
        );
        // And not by luck: the planted rule is cleanly recoverable.
        assert!(model_correct >= 20, "planted rule must be recovered, got {model_correct}/24");
    }

    #[test]
    fn property_planted_rule_recovers_on_random_corpora() {
        crate::util::propcheck::check(3, |rng| {
            let n = 20 + rng.below(10);
            let mut corpus = Vec::new();
            for i in 0..n {
                let hi = rng.below(2) == 1;
                let r = if hi { 0.65 + 0.3 * rng.f64() } else { 0.35 * rng.f64() };
                corpus.push(planted_row(i, r));
            }
            // Hold out the last 4; train on the rest.
            let (train_rows, held) = corpus.split_at(corpus.len() - 4);
            let m = CostModel::train(train_rows).ok_or("training failed")?;
            for row in held {
                let pick = m
                    .predict(&row.features, ReorderPolicy::Never)
                    .ok_or("no prediction")?
                    .kind;
                if pick != row.kind {
                    return Err(format!(
                        "planted rule not recovered: scatter {:.2} -> {}",
                        row.features.scatter_ratio,
                        pick.label()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn model_json_round_trips_and_is_deterministic() {
        let corpus = planted_corpus();
        let m1 = CostModel::train(&corpus).unwrap();
        let m2 = CostModel::train(&corpus).unwrap();
        let dump1 = m1.to_json().dump();
        assert_eq!(dump1, m2.to_json().dump(), "same corpus must give a byte-identical model");
        let back = CostModel::from_json(&Json::parse(&dump1).unwrap()).expect("model parses");
        assert_eq!(back.to_json().dump(), dump1, "round-trip is exact");
        // Predictions survive the round-trip.
        for row in corpus.iter().take(4) {
            let a = m1.predict(&row.features, ReorderPolicy::Never).unwrap();
            let b = back.predict(&row.features, ReorderPolicy::Never).unwrap();
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.reordered, b.reordered);
            assert!((a.confidence - b.confidence).abs() < 1e-12);
        }
        // Garbage shapes are rejected, not mis-read.
        assert!(CostModel::from_json(&Json::parse("{}").unwrap()).is_none());
        assert!(CostModel::from_json(&Json::parse("{\"version\": 99}").unwrap()).is_none());
        // Non-finite / degenerate values are rejected at load, not
        // discovered as a NaN-softmax panic inside predict (a
        // hand-edited `1e999` parses as +inf).
        let mut poisoned = Json::parse(&dump1).unwrap();
        if let Json::Obj(map) = &mut poisoned {
            if let Some(Json::Arr(ws)) = map.get_mut("weights") {
                if let Json::Arr(w0) = &mut ws[0] {
                    w0[0] = Json::Num(f64::INFINITY);
                }
            }
        }
        assert!(CostModel::from_json(&poisoned).is_none(), "inf weights must be rejected");
        let mut degenerate = Json::parse(&dump1).unwrap();
        if let Json::Obj(map) = &mut degenerate {
            if let Some(Json::Arr(stds)) = map.get_mut("std") {
                stds[0] = Json::Num(0.0);
            }
        }
        assert!(CostModel::from_json(&degenerate).is_none(), "zero std must be rejected");
        // A model trained under a different feature layout (same shape,
        // different names) must decline, not multiply weights by the
        // wrong features.
        let mut relabeled = Json::parse(&dump1).unwrap();
        if let Json::Obj(map) = &mut relabeled {
            if let Some(Json::Arr(names)) = map.get_mut("feature_names") {
                names[0] = Json::Str("some_future_feature".into());
            }
        }
        assert!(
            CostModel::from_json(&relabeled).is_none(),
            "a foreign feature layout must be rejected"
        );
    }

    #[test]
    fn thread_pick_follows_the_trained_rate_surface() {
        // Rung rates grow with p in the planted corpus ⇒ the regressors
        // must send parallel picks to the top rung, sequential to 1, and
        // never past the caller's budget.
        let m = CostModel::train(&planted_corpus()).unwrap();
        let f = feat(5000, 0.8, 8, 8, 4);
        assert_eq!(m.predict_threads(&f, EngineKind::Colorful, 4), 4);
        assert_eq!(m.predict_threads(&f, EngineKind::Sequential, 4), 1);
        assert!(m.predict_threads(&f, EngineKind::Colorful, 2) <= 2);
        // With no applicable rung the parallel pick takes the budget.
        assert_eq!(m.predict_threads(&f, EngineKind::Colorful, 1), 1);
    }

    #[test]
    fn block_pick_follows_the_trained_width_surface() {
        // Per-vector rates in the planted corpus peak at k = 4 ⇒ the
        // width regressors must send the pick there, clamp to the
        // caller's ceiling, and degrade to the heuristic when the
        // corpus carries no block axis.
        let m = CostModel::train(&planted_corpus()).unwrap();
        let f = feat(5000, 0.8, 8, 8, 4);
        assert_eq!(m.predict_block_k(&f, 8), 4);
        assert!(m.predict_block_k(&f, 2) <= 2, "pick must respect the ceiling");
        // A pre-block-axis corpus trains no width regressors: the model
        // answers with the structural heuristic instead of guessing.
        let legacy: Vec<CorpusRow> = planted_corpus()
            .into_iter()
            .map(|mut r| {
                r.block_rates.clear();
                r
            })
            .collect();
        let m0 = CostModel::train(&legacy).unwrap();
        assert_eq!(
            m0.predict_block_k(&f, 8),
            super::super::heuristic_block_k(&f),
            "no width surface ⇒ heuristic fallback"
        );
        // And the width surface survives the JSON round-trip.
        let back =
            CostModel::from_json(&Json::parse(&m.to_json().dump()).unwrap()).expect("parses");
        assert_eq!(back.predict_block_k(&f, 8), 4);
        // A legacy model *file* (no kblocks key) loads with no width
        // surface rather than being rejected.
        let mut stripped = m.to_json();
        if let Json::Obj(map) = &mut stripped {
            map.remove("kblocks");
        }
        let old = CostModel::from_json(&stripped).expect("pre-block-axis files still load");
        assert_eq!(old.predict_block_k(&f, 8), super::super::heuristic_block_k(&f));
    }

    #[test]
    fn never_policy_restricts_to_plain_classes() {
        // A corpus whose high-scatter winners are *reordered* colorful:
        // Measure may pick the reordered class, Never must not.
        let corpus: Vec<CorpusRow> = (0..12)
            .map(|i| {
                let mut r = planted_row(i, if i % 2 == 0 { 0.2 } else { 0.8 });
                if i % 2 == 1 {
                    r.reordered = true;
                }
                r
            })
            .collect();
        let m = CostModel::train(&corpus).unwrap();
        let hi = feat(5000, 0.8, 8, 8, 4);
        let measure = m.predict(&hi, ReorderPolicy::Measure).unwrap();
        assert!(measure.reordered, "high scatter learned as a reordered winner");
        let never = m.predict(&hi, ReorderPolicy::Never).unwrap();
        assert!(!never.reordered, "Never must not pick a reordered class");
        assert_eq!(never.kind, EngineKind::LocalBuffers(AccumMethod::Interval));
        let always = m.predict(&feat(5000, 0.2, 8, 8, 4), ReorderPolicy::Always).unwrap();
        assert!(always.reordered, "Always forces the ordering on any winner");
    }

    #[test]
    fn class_label_round_trips() {
        for label in ["colorful", "reordered/colorful", "local-buffers/interval"] {
            let c = ClassLabel::parse(label).unwrap();
            assert_eq!(c.label(), label);
        }
        assert!(ClassLabel::parse("auto").is_none(), "Auto is a selector, not a class");
        assert!(ClassLabel::parse("reordered/auto").is_none());
        assert!(ClassLabel::parse("nonsense").is_none());
    }
}
