//! Dependency-free trainers for the learned cost model: a multinomial
//! (softmax) logistic classifier fit by full-batch gradient descent and
//! a closed-form ridge regressor solved via the normal equations. Both
//! are deterministic — zero initialization, fixed iteration counts,
//! rows visited in the caller's (sorted) order — so the same corpus
//! always yields a byte-identical model file.

/// L2 regularization weight shared by both trainers — small enough not
/// to blunt a clean structural rule, large enough to keep tiny corpora
/// from blowing weights up.
pub(super) const LAMBDA: f64 = 1e-3;
/// Full-batch gradient steps for the classifier.
const ITERS: usize = 400;
/// Step size — safe for standardized features (unit variance).
const LR: f64 = 0.5;

pub(super) fn dot(w: &[f64], x: &[f64]) -> f64 {
    w.iter().zip(x).map(|(a, b)| a * b).sum()
}

/// In-place stable softmax (shift by the max before exponentiating).
pub(super) fn softmax_in_place(z: &mut [f64]) {
    let m = z.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut sum = 0.0;
    for v in z.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    if sum > 0.0 {
        for v in z.iter_mut() {
            *v /= sum;
        }
    }
}

/// Fit softmax weights. `x` rows are standardized features *with* a
/// trailing bias 1.0; `y[i]` is row i's class index; `nclasses` the
/// class count. Returns one weight vector per class (same layout as the
/// rows). The bias column is not weight-decayed (standard practice —
/// the intercept carries the class prior).
pub(super) fn fit_softmax(x: &[Vec<f64>], y: &[usize], nclasses: usize) -> Vec<Vec<f64>> {
    let nfeat = x.first().map(|r| r.len()).unwrap_or(0);
    let mut w = vec![vec![0.0; nfeat]; nclasses];
    if x.is_empty() || nclasses == 0 {
        return w;
    }
    let inv_rows = 1.0 / x.len() as f64;
    let mut grad = vec![vec![0.0; nfeat]; nclasses];
    let mut probs = vec![0.0; nclasses];
    for _ in 0..ITERS {
        for g in grad.iter_mut() {
            for v in g.iter_mut() {
                *v = 0.0;
            }
        }
        for (row, &cls) in x.iter().zip(y) {
            for (c, p) in probs.iter_mut().enumerate() {
                *p = dot(&w[c], row);
            }
            softmax_in_place(&mut probs);
            for (c, g) in grad.iter_mut().enumerate() {
                let err = probs[c] - if c == cls { 1.0 } else { 0.0 };
                for (gj, &xj) in g.iter_mut().zip(row) {
                    *gj += err * xj;
                }
            }
        }
        for (c, wc) in w.iter_mut().enumerate() {
            for (j, wj) in wc.iter_mut().enumerate() {
                let reg = if j + 1 == nfeat { 0.0 } else { LAMBDA * *wj };
                *wj -= LR * (grad[c][j] * inv_rows + reg);
            }
        }
    }
    w
}

/// Closed-form ridge regression `argmin ‖Xw − y‖² + λ‖w‖²` via the
/// normal equations, solved by Gaussian elimination with partial
/// pivoting. Rows carry the trailing bias 1.0 (regularizing the bias
/// too is harmless at λ = 1e-3 and keeps the system strictly positive
/// definite even for degenerate corpora).
pub(super) fn fit_ridge(x: &[Vec<f64>], y: &[f64]) -> Vec<f64> {
    let nfeat = x.first().map(|r| r.len()).unwrap_or(0);
    if nfeat == 0 {
        return Vec::new();
    }
    // Augmented system [XᵀX + λI | Xᵀy].
    let mut a = vec![vec![0.0; nfeat + 1]; nfeat];
    for (row, &t) in x.iter().zip(y) {
        for i in 0..nfeat {
            for j in 0..nfeat {
                a[i][j] += row[i] * row[j];
            }
            a[i][nfeat] += row[i] * t;
        }
    }
    for (i, ai) in a.iter_mut().enumerate() {
        ai[i] += LAMBDA;
    }
    for col in 0..nfeat {
        let pivot = (col..nfeat)
            .max_by(|&p, &q| a[p][col].abs().partial_cmp(&a[q][col].abs()).expect("finite"))
            .expect("non-empty range");
        a.swap(col, pivot);
        let diag = a[col][col];
        if diag.abs() < 1e-12 {
            continue;
        }
        for r in col + 1..nfeat {
            let f = a[r][col] / diag;
            if f == 0.0 {
                continue;
            }
            for c in col..=nfeat {
                a[r][c] -= f * a[col][c];
            }
        }
    }
    let mut w = vec![0.0; nfeat];
    for i in (0..nfeat).rev() {
        let mut v = a[i][nfeat];
        for j in i + 1..nfeat {
            v -= a[i][j] * w[j];
        }
        w[i] = if a[i][i].abs() < 1e-12 { 0.0 } else { v / a[i][i] };
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_separates_a_one_dimensional_rule() {
        // Feature = [v, bias]; class 1 iff v > 0. Clean margin.
        let x: Vec<Vec<f64>> = (0..20)
            .map(|i| {
                let v = if i % 2 == 0 { -1.0 - 0.05 * i as f64 } else { 1.0 + 0.05 * i as f64 };
                vec![v, 1.0]
            })
            .collect();
        let y: Vec<usize> = (0..20).map(|i| i % 2).collect();
        let w = fit_softmax(&x, &y, 2);
        for (row, &cls) in x.iter().zip(&y) {
            let s0 = dot(&w[0], row);
            let s1 = dot(&w[1], row);
            assert_eq!((s1 > s0) as usize, cls, "row {row:?}");
        }
    }

    #[test]
    fn ridge_recovers_a_linear_trend() {
        // y = 3v + 1 exactly; ridge with tiny λ lands within 1%.
        let x: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64 / 10.0, 1.0]).collect();
        let y: Vec<f64> = x.iter().map(|r| 3.0 * r[0] + 1.0).collect();
        let w = fit_ridge(&x, &y);
        assert!((w[0] - 3.0).abs() < 0.05, "slope {w:?}");
        assert!((w[1] - 1.0).abs() < 0.05, "intercept {w:?}");
    }

    #[test]
    fn trainers_are_deterministic() {
        let x: Vec<Vec<f64>> = (0..8).map(|i| vec![(i as f64).sin(), 1.0]).collect();
        let y: Vec<usize> = (0..8).map(|i| i % 2).collect();
        let w1 = fit_softmax(&x, &y, 2);
        let w2 = fit_softmax(&x, &y, 2);
        assert_eq!(w1, w2);
        let t: Vec<f64> = (0..8).map(|i| i as f64).collect();
        assert_eq!(fit_ridge(&x, &t), fit_ridge(&x, &t));
    }
}
