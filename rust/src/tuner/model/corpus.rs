//! Corpus loader: flatten persisted decision-cache files (schema v1 and
//! v2) into labeled training rows for the learned cost model. Every
//! measured decision already records exactly the features the model
//! needs (`Features`), the winner it should predict (`kind`, `reorder`,
//! `nthreads`) and — for swept decisions — the rate surface the thread
//! regressors fit; the cache is a free training set.

use super::super::{cache, Decision, Features};
use crate::parallel::EngineKind;
use crate::util::error::{msg, Result};
use std::path::Path;

/// One labeled training example.
#[derive(Clone, Debug)]
pub struct CorpusRow {
    /// Structure fingerprint — part of the deterministic sort key.
    pub fingerprint: u64,
    /// Thread budget the decision was tuned under (the cache key's
    /// second half).
    pub max_threads: usize,
    pub features: Features,
    /// The measured winner the classifier learns to predict.
    pub kind: EngineKind,
    /// Whether the winner ran through the RCM ordering.
    pub reordered: bool,
    /// The winning thread count.
    pub nthreads: usize,
    /// Best measured rate per thread-ladder rung — the sweep surface
    /// when recorded, else the single measured point.
    pub rung_rates: Vec<(usize, f64)>,
    /// Per-vector rate at each block width the winner was re-measured
    /// at (the block-size axis); empty for pre-block-axis decisions.
    pub block_rates: Vec<(usize, f64)>,
}

/// Flatten decisions into training rows. Only *measured* decisions
/// qualify — heuristic and model placeholders carry no signal about
/// what actually won. Rows are sorted by (fingerprint × max_threads) so
/// training, and therefore the serialized model, is deterministic
/// regardless of file or hash-map order, and deduplicated on that same
/// key (first occurrence wins): the same matrix persisted into several
/// cache files must not be over-weighted in the classifier or the rung
/// regressors.
pub fn rows_from_decisions(decisions: &[Decision]) -> Vec<CorpusRow> {
    let mut rows: Vec<CorpusRow> = decisions
        .iter()
        .filter(|d| d.measured && d.kind != EngineKind::Auto)
        .map(|d| {
            let mut rung_rates: Vec<(usize, f64)> = d
                .sweep
                .iter()
                .filter_map(|pt| pt.best().map(|b| (pt.nthreads, b.mflops)))
                .collect();
            if rung_rates.is_empty() && d.mflops > 0.0 {
                rung_rates.push((d.nthreads, d.mflops));
            }
            CorpusRow {
                fingerprint: d.fingerprint,
                max_threads: d.max_threads,
                features: d.features.clone(),
                kind: d.kind,
                reordered: d.reorder,
                nthreads: d.nthreads,
                rung_rates,
                block_rates: d.block_rates.clone(),
            }
        })
        .collect();
    // Stable sort + dedup: among duplicates the first in input order
    // (file order for `load_corpus`) survives.
    rows.sort_by_key(|r| (r.fingerprint, r.max_threads));
    rows.dedup_by_key(|r| (r.fingerprint, r.max_threads));
    rows
}

/// Load every decision-cache JSON file under `path` — a single file, or
/// a directory scanned (non-recursively) for `*.json` — into training
/// rows. Unparseable files are skipped with a warning: a corpus is an
/// accumulation artifact, partial is normal. A missing path is an
/// error; an empty result is the caller's problem to report.
pub fn load_corpus(path: &Path) -> Result<Vec<CorpusRow>> {
    let mut files: Vec<std::path::PathBuf> = Vec::new();
    if path.is_dir() {
        let entries = std::fs::read_dir(path)
            .map_err(|e| msg(format!("read corpus dir {}: {e}", path.display())))?;
        for entry in entries {
            let p = entry
                .map_err(|e| msg(format!("read corpus dir {}: {e}", path.display())))?
                .path();
            if p.extension().and_then(|e| e.to_str()) == Some("json") {
                files.push(p);
            }
        }
        files.sort();
    } else if path.is_file() {
        files.push(path.to_path_buf());
    } else {
        return Err(msg(format!("corpus path {} does not exist", path.display())));
    }
    let mut decisions = Vec::new();
    for f in &files {
        match cache::load_decisions_file(f) {
            Ok(mut ds) => decisions.append(&mut ds),
            Err(e) => eprintln!("warning: skipping corpus file {}: {e}", f.display()),
        }
    }
    Ok(rows_from_decisions(&decisions))
}

#[cfg(test)]
mod tests {
    use super::super::super::{DecisionCache, Provenance, SweepPoint, TrialResult};
    use super::*;
    use crate::parallel::AccumMethod;

    fn features(n: usize, p: usize) -> Features {
        Features {
            n,
            work_flops: 9 * n,
            scatter_pairs: n / 2,
            scatter_ratio: 0.5,
            bandwidth: n / 10,
            window_rows: 2 * n,
            window_shrink: 2.0 / p as f64,
            colors: 4,
            intervals: 6,
            balance: 1.05,
            nthreads: p,
        }
    }

    fn trial(kind: EngineKind, mflops: f64) -> TrialResult {
        TrialResult {
            kind,
            reordered: false,
            seconds_per_product: 1e-4,
            mad_s: 0.0,
            mflops,
        }
    }

    fn swept_decision(fp: u64, kind: EngineKind) -> Decision {
        Decision {
            kind,
            reorder: false,
            mflops: 200.0,
            measured: true,
            provenance: Provenance::Measured,
            served_mflops: 0.0,
            tuned_s: 0.01,
            fingerprint: fp,
            nthreads: 2,
            max_threads: 2,
            features: features(500, 2),
            trials: vec![trial(kind, 200.0)],
            sweep: vec![
                SweepPoint { nthreads: 1, trials: vec![trial(EngineKind::Sequential, 90.0)] },
                SweepPoint { nthreads: 2, trials: vec![trial(kind, 200.0)] },
            ],
            block_k: 4,
            block_rates: vec![(1, 200.0), (2, 230.0), (4, 260.0), (8, 250.0)],
        }
    }

    #[test]
    fn rows_keep_measured_decisions_only_and_sort() {
        let mut unmeasured = swept_decision(9, EngineKind::Colorful);
        unmeasured.measured = false;
        unmeasured.provenance = Provenance::Heuristic;
        let decisions = vec![
            swept_decision(7, EngineKind::Colorful),
            unmeasured,
            swept_decision(3, EngineKind::LocalBuffers(AccumMethod::Effective)),
        ];
        let rows = rows_from_decisions(&decisions);
        assert_eq!(rows.len(), 2, "unmeasured decisions are not training data");
        assert_eq!(rows[0].fingerprint, 3, "rows sort by fingerprint");
        assert_eq!(rows[1].fingerprint, 7);
        // The same (fingerprint × max_threads) appearing again — e.g.
        // the same matrix persisted into two cache files — must not be
        // over-weighted: duplicates collapse, first occurrence wins.
        let mut dup = vec![
            swept_decision(7, EngineKind::Colorful),
            swept_decision(7, EngineKind::Atomic),
            swept_decision(3, EngineKind::LocalBuffers(AccumMethod::Effective)),
        ];
        let rows = rows_from_decisions(&dup);
        assert_eq!(rows.len(), 2, "duplicate entries collapse");
        assert_eq!(rows[1].kind, EngineKind::Colorful, "first occurrence wins");
        dup.swap(0, 1);
        assert_eq!(rows_from_decisions(&dup)[1].kind, EngineKind::Atomic);
        assert_eq!(rows[0].kind, EngineKind::LocalBuffers(AccumMethod::Effective));
        // The sweep surface flattens into per-rung best rates, and the
        // block axis rides along verbatim.
        assert_eq!(rows[1].rung_rates, vec![(1, 90.0), (2, 200.0)]);
        assert_eq!(rows[1].block_rates, vec![(1, 200.0), (2, 230.0), (4, 260.0), (8, 250.0)]);
    }

    #[test]
    fn single_p_decisions_contribute_their_one_point() {
        let mut d = swept_decision(1, EngineKind::Atomic);
        d.sweep.clear();
        let rows = rows_from_decisions(&[d]);
        assert_eq!(rows[0].rung_rates, vec![(2, 200.0)]);
    }

    #[test]
    fn load_corpus_walks_a_directory_of_cache_files() {
        let dir = std::env::temp_dir().join(format!("csrc_corpus_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // One v2 file written by the cache itself…
        {
            let cache = DecisionCache::open(&dir.join("a.json"));
            cache.put(swept_decision(5, EngineKind::Colorful));
        }
        // …one hand-rolled v1 file (no max_threads, no sweep)…
        std::fs::write(
            dir.join("b.json"),
            r#"{
                "version": 1,
                "decisions": [{
                    "fingerprint": "0000000000000002",
                    "nthreads": 3,
                    "kind": "colorful",
                    "mflops": 55.5,
                    "measured": true,
                    "tuned_s": 0.02,
                    "features": {
                        "n": 64, "work_flops": 500, "scatter_pairs": 100,
                        "scatter_ratio": 0.7, "bandwidth": 9, "colors": 3,
                        "intervals": 5, "balance": 1.01, "feat_nthreads": 3
                    },
                    "trials": [{
                        "kind": "colorful", "seconds_per_product": 1.0e-4,
                        "mad_s": 1.0e-6, "mflops": 55.5
                    }]
                }]
            }"#,
        )
        .unwrap();
        // …one file that is not a decision cache at all (skipped with a
        // warning), and one non-json file (never read).
        std::fs::write(dir.join("c.json"), "not json at all").unwrap();
        std::fs::write(dir.join("readme.txt"), "ignore me").unwrap();
        let rows = load_corpus(&dir).unwrap();
        assert_eq!(rows.len(), 2, "v1 + v2 entries load; garbage is skipped");
        assert_eq!(rows[0].fingerprint, 2);
        assert_eq!(rows[0].rung_rates, vec![(3, 55.5)], "v1 entries carry one point");
        assert!(rows[0].block_rates.is_empty(), "pre-block-axis entries have no k surface");
        assert_eq!(rows[1].fingerprint, 5);
        // A single file works too.
        let one = load_corpus(&dir.join("a.json")).unwrap();
        assert_eq!(one.len(), 1);
        // A missing path is a hard error, not an empty corpus.
        assert!(load_corpus(&dir.join("nope.json")).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
