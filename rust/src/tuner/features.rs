//! Structural feature extraction and fingerprinting — the analysis half
//! of the autotuner.
//!
//! A [`Features`] vector captures everything the cost model looks at and
//! everything a persisted decision records about *why* an engine won: it
//! depends only on the matrix pattern and the plan, never on the values.
//! [`fingerprint`] hashes the same structure into the key of the
//! persistent [`super::DecisionCache`], so a matrix that is re-registered
//! (or reloaded by a restarted service) maps back to its known decision.

use crate::plan::SpmvPlan;
use crate::sparse::SpmvKernel;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Structure-only description of one matrix × thread-count.
#[derive(Clone, Debug, PartialEq)]
pub struct Features {
    /// Matrix order.
    pub n: usize,
    /// Total row-sweep work in the kernel's flop-ish units (the Mflop/s
    /// proxy trials are normalized by — only ratios between candidates
    /// matter).
    pub work_flops: usize,
    /// Off-diagonal mirrored pairs the sweep scatters (0 for
    /// scatter-free kernels such as CSR).
    pub scatter_pairs: usize,
    /// Fraction of sweep writes produced by scatters: 2k / (n + 2k).
    pub scatter_ratio: f64,
    /// Off-diagonal half-bandwidth of the *write* pattern: max over rows
    /// of `i - row_write_lo(i)`.
    pub bandwidth: usize,
    /// Total windowed-buffer rows `Σ_t |eff[t]|` — what the windowed
    /// local-buffers engines allocate, zero and accumulate (equals n for
    /// scatter-free kernels; `p·n` when the plan lacks ranges).
    pub window_rows: usize,
    /// `window_rows / (p·n)`: the fraction of the pre-windowing
    /// full-length layout the windowed buffers still occupy. Low values
    /// mean the effective ranges are tight (banded or RCM-reordered
    /// patterns) and the local-buffers overhead is near its floor.
    pub window_shrink: f64,
    /// Conflict colors (0 when the plan lacks the coloring piece).
    pub colors: usize,
    /// Interval count of the §3.1 decomposition (0 when absent).
    pub intervals: usize,
    /// Thread work imbalance over the plan's partition, max/avg (≥ 1 for
    /// non-degenerate partitions).
    pub balance: f64,
    /// Thread count the plan (and therefore `intervals`/`balance`) was
    /// computed for.
    pub nthreads: usize,
}

impl Features {
    /// Names of the derived feature vector the learned cost model
    /// ([`super::model`]) trains on, index-aligned with
    /// [`Features::raw_vector`]. Persisted in model files so a reader
    /// can tell what each weight multiplies.
    pub const RAW_FEATURE_NAMES: [&'static str; 10] = [
        "log_n",
        "log_work_per_row",
        "scatter_ratio",
        "rel_bandwidth",
        "window_shrink",
        "log_colors",
        "log_intervals",
        "balance",
        "log_nthreads",
        "log_work",
    ];

    /// Derived feature vector for the learned cost model: log-compressed
    /// sizes, ratios already in [0, 1], and the write bandwidth relative
    /// to the order — dimensionless numbers, so a model trained on small
    /// matrices transfers to big ones instead of memorizing scales.
    pub fn raw_vector(&self) -> [f64; 10] {
        let n = self.n.max(1) as f64;
        [
            (1.0 + self.n as f64).ln(),
            (1.0 + self.work_flops as f64 / n).ln(),
            self.scatter_ratio,
            (1.0 + self.bandwidth as f64) / (1.0 + n),
            self.window_shrink,
            (1.0 + self.colors as f64).ln(),
            (1.0 + self.intervals as f64).ln(),
            self.balance,
            (1.0 + self.nthreads as f64).ln(),
            (1.0 + self.work_flops as f64).ln(),
        ]
    }

    /// Extract features from a kernel and the plan built for it. Cheap:
    /// one O(nnz) pass plus reads of what the plan already computed.
    pub fn extract(kernel: &dyn SpmvKernel, plan: &SpmvPlan) -> Features {
        let n = kernel.dim();
        let mut work_flops = 0usize;
        let mut scatter_pairs = 0usize;
        let mut bandwidth = 0usize;
        for i in 0..n {
            work_flops += kernel.row_work(i);
            bandwidth = bandwidth.max(i - kernel.row_write_lo(i));
            kernel.scatter_targets(i, &mut |_| scatter_pairs += 1);
        }
        let denom = n + 2 * scatter_pairs;
        let scatter_ratio =
            if denom == 0 { 0.0 } else { 2.0 * scatter_pairs as f64 / denom as f64 };
        let p = plan.nthreads;
        let works: Vec<f64> = (0..p)
            .map(|t| plan.part.block(t).map(|i| kernel.row_work(i) as f64).sum())
            .collect();
        let max = works.iter().cloned().fold(0.0, f64::max);
        let avg = works.iter().sum::<f64>() / p as f64;
        let window_rows = plan
            .eff
            .as_ref()
            .map(|eff| eff.iter().map(|r| r.len()).sum())
            .unwrap_or(p * n);
        let full = p * n;
        Features {
            n,
            work_flops,
            scatter_pairs,
            scatter_ratio,
            bandwidth,
            window_rows,
            window_shrink: if full > 0 { window_rows as f64 / full as f64 } else { 1.0 },
            colors: plan.colors.as_ref().map(|c| c.num_colors()).unwrap_or(0),
            intervals: plan.ints.as_ref().map(|v| v.len()).unwrap_or(0),
            balance: if avg > 0.0 { max / avg } else { 1.0 },
            nthreads: p,
        }
    }
}

fn mix(h: &mut u64, v: u64) {
    for b in v.to_le_bytes() {
        *h = (*h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
}

/// FNV-1a over the matrix *structure*: format name, order, per-row work,
/// write extents and scatter targets. Values are excluded on purpose —
/// the §3 schedules depend only on the pattern, so two matrices with the
/// same pattern (e.g. successive FEM assemblies on one mesh) share one
/// tuning decision.
pub fn fingerprint(kernel: &dyn SpmvKernel) -> u64 {
    let mut h = FNV_OFFSET;
    for b in kernel.kernel_name().bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    let n = kernel.dim();
    mix(&mut h, n as u64);
    for i in 0..n {
        mix(&mut h, kernel.row_work(i) as u64);
        mix(&mut h, kernel.row_write_lo(i) as u64);
        kernel.scatter_targets(i, &mut |j| mix(&mut h, j as u64));
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::PlanBuilder;
    use crate::sparse::{Coo, Csr, Csrc};
    use crate::util::Rng;

    fn coo(n: usize, seed: u64) -> Coo {
        let mut rng = Rng::new(seed);
        Coo::random_structurally_symmetric(n, 4, false, &mut rng)
    }

    #[test]
    fn features_distinguish_scattering_from_scatter_free() {
        let c = coo(120, 1);
        let csrc = Csrc::from_coo(&c).unwrap();
        let csr = Csr::from_coo(&c);
        let plan_csrc = PlanBuilder::all(3).build(&csrc);
        let plan_csr = PlanBuilder::all(3).build(&csr);
        let fc = Features::extract(&csrc, &plan_csrc);
        let fr = Features::extract(&csr, &plan_csr);
        assert_eq!(fc.n, 120);
        assert!(fc.scatter_pairs > 0 && fc.scatter_ratio > 0.0);
        assert!(fc.bandwidth > 0);
        assert!(fc.colors > 1, "CSRC sweeps conflict");
        assert!(fc.intervals >= 1);
        assert!(fc.balance >= 1.0 - 1e-12);
        // Windowed buffers: at least one slot per row, never more than
        // the full p·n layout.
        assert!(fc.window_rows >= 120 && fc.window_rows <= 3 * 120);
        assert!(fc.window_shrink > 0.0 && fc.window_shrink <= 1.0);
        // CSR scatters nothing: one color, zero write bandwidth below i,
        // block-exact windows (Σ|eff| == n — the minimum possible).
        assert_eq!(fr.scatter_pairs, 0);
        assert_eq!(fr.scatter_ratio, 0.0);
        assert_eq!(fr.bandwidth, 0);
        assert_eq!(fr.colors, 1);
        assert_eq!(fr.window_rows, 120);
        assert!(fr.window_shrink <= fc.window_shrink + 1e-12);
    }

    #[test]
    fn raw_vector_is_aligned_and_finite() {
        let c = coo(100, 5);
        let csrc = Csrc::from_coo(&c).unwrap();
        let plan = PlanBuilder::all(2).build(&csrc);
        let f = Features::extract(&csrc, &plan);
        let v = f.raw_vector();
        assert_eq!(v.len(), Features::RAW_FEATURE_NAMES.len());
        assert!(v.iter().all(|x| x.is_finite()));
        // Ratios stay dimensionless: scatter_ratio, rel_bandwidth and
        // window_shrink all live in [0, 1].
        for idx in [2usize, 3, 4] {
            assert!(
                (0.0..=1.0).contains(&v[idx]),
                "{} = {} out of range",
                Features::RAW_FEATURE_NAMES[idx],
                v[idx]
            );
        }
    }

    #[test]
    fn fingerprint_is_structural() {
        let c = coo(80, 2);
        let a = Csrc::from_coo(&c).unwrap();
        // Same pattern, different values → same fingerprint.
        let mut c2 = c.clone();
        for v in &mut c2.vals {
            *v *= 3.0;
        }
        let b = Csrc::from_coo(&c2).unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&b));
        // Different pattern → (overwhelmingly) different fingerprint.
        let other = Csrc::from_coo(&coo(80, 3)).unwrap();
        assert_ne!(fingerprint(&a), fingerprint(&other));
        // Same pattern through a different kernel format → different key
        // (decisions are per-kernel: CSR and CSRC schedules differ).
        let csr = Csr::from_coo(&c);
        assert_ne!(fingerprint(&a), fingerprint(&csr));
    }
}
