"""L1 correctness: Pallas CSRC-ELL kernel vs the pure-jnp oracle.

hypothesis sweeps (n, w, seed, density, dtype); every case asserts
allclose against ref.py AND against a dense reconstruction + matmul.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.csrc_spmv import (
    csrc_spmv,
    csrc_spmv_t,
    vmem_bytes,
    mxu_utilization,
)
from compile.kernels.ref import (
    ref_spmv_ell,
    ref_spmv_t_ell,
    dense_from_ell,
    random_csrc_ell,
)


def _x(n, seed, dtype=np.float32):
    return np.random.default_rng(seed + 1000).standard_normal(n).astype(dtype)


# ---------------------------------------------------------------- unit tests

def test_identity_matrix():
    """Diagonal-only matrix: y == ad * x."""
    n, w = 64, 4
    ad = np.arange(1, n + 1, dtype=np.float32)
    al = np.zeros((n, w), np.float32)
    au = np.zeros((n, w), np.float32)
    ja = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, w))
    x = _x(n, 0)
    y = csrc_spmv(ad, al, au, ja, x)
    np.testing.assert_allclose(np.asarray(y), ad * x, rtol=1e-6)


def test_single_offdiag_pair():
    """One lower entry a_{5,2}=3 with upper mirror a_{2,5}=7."""
    n, w = 64, 2
    ad = np.ones(n, np.float32)
    al = np.zeros((n, w), np.float32)
    au = np.zeros((n, w), np.float32)
    ja = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, w))
    al[5, 0], au[5, 0], ja[5, 0] = 3.0, 7.0, 2
    x = _x(n, 1)
    y = np.asarray(csrc_spmv(ad, al, au, ja, x))
    expect = x.copy()
    expect[5] += 3.0 * x[2]
    expect[2] += 7.0 * x[5]
    np.testing.assert_allclose(y, expect, rtol=1e-6)


def test_matches_dense_reconstruction():
    n, w = 128, 8
    ad, al, au, ja = random_csrc_ell(n, w, seed=7)
    x = _x(n, 7)
    a = dense_from_ell(ad, al, au, ja)
    y = np.asarray(csrc_spmv(ad, al, au, ja, x))
    np.testing.assert_allclose(y, a @ x, rtol=2e-5, atol=2e-5)


def test_transpose_swaps_al_au():
    n, w = 128, 8
    ad, al, au, ja = random_csrc_ell(n, w, seed=11)
    x = _x(n, 11)
    a = dense_from_ell(ad, al, au, ja)
    yt = np.asarray(csrc_spmv_t(ad, al, au, ja, x))
    np.testing.assert_allclose(yt, a.T @ x, rtol=2e-5, atol=2e-5)


def test_numeric_symmetric_transpose_is_identity():
    """Numerically symmetric matrix: A x == A.T x exactly (same arrays)."""
    n, w = 64, 4
    ad, al, au, ja = random_csrc_ell(n, w, seed=3, numeric_symmetric=True)
    x = _x(n, 3)
    y = np.asarray(csrc_spmv(ad, al, au, ja, x))
    yt = np.asarray(csrc_spmv_t(ad, al, au, ja, x))
    np.testing.assert_allclose(y, yt, rtol=1e-6)


def test_block_n_invariance():
    """The grid block size must not change the result."""
    n, w = 128, 8
    ad, al, au, ja = random_csrc_ell(n, w, seed=5)
    x = _x(n, 5)
    y32 = np.asarray(csrc_spmv(ad, al, au, ja, x, block_n=32))
    y64 = np.asarray(csrc_spmv(ad, al, au, ja, x, block_n=64))
    y128 = np.asarray(csrc_spmv(ad, al, au, ja, x, block_n=128))
    # Accumulation order differs across block sizes: f32 round-off only.
    np.testing.assert_allclose(y32, y64, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(y64, y128, rtol=1e-5, atol=1e-6)


def test_rejects_indivisible_block():
    n, w = 96, 4
    ad, al, au, ja = random_csrc_ell(n, w, seed=2)
    with pytest.raises(ValueError, match="not divisible"):
        csrc_spmv(ad, al, au, ja, _x(n, 2), block_n=64)


def test_zero_vector():
    n, w = 64, 4
    ad, al, au, ja = random_csrc_ell(n, w, seed=9)
    y = np.asarray(csrc_spmv(ad, al, au, ja, np.zeros(n, np.float32)))
    np.testing.assert_allclose(y, np.zeros(n), atol=0)


def test_linearity():
    """A(ax + by) == a*Ax + b*Ay — catches any stateful accumulation bug."""
    n, w = 64, 4
    ad, al, au, ja = random_csrc_ell(n, w, seed=13)
    x1, x2 = _x(n, 13), _x(n, 14)
    lhs = np.asarray(csrc_spmv(ad, al, au, ja, (2.0 * x1 + 3.0 * x2).astype(np.float32)))
    rhs = 2.0 * np.asarray(csrc_spmv(ad, al, au, ja, x1)) + 3.0 * np.asarray(
        csrc_spmv(ad, al, au, ja, x2)
    )
    np.testing.assert_allclose(lhs, rhs, rtol=3e-5, atol=3e-5)


# --------------------------------------------------------- hypothesis sweeps

@settings(max_examples=25, deadline=None)
@given(
    n_blocks=st.integers(1, 4),
    w=st.integers(1, 12),
    seed=st.integers(0, 2**16),
    density=st.floats(0.0, 1.0),
)
def test_kernel_vs_ref_sweep(n_blocks, w, seed, density):
    n = 32 * n_blocks
    ad, al, au, ja = random_csrc_ell(n, w, seed=seed, density=density)
    x = _x(n, seed)
    got = np.asarray(csrc_spmv(ad, al, au, ja, x, block_n=32))
    want = np.asarray(ref_spmv_ell(ad, al, au, jnp.asarray(ja), x))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_transpose_vs_ref_sweep(seed):
    n, w = 64, 6
    ad, al, au, ja = random_csrc_ell(n, w, seed=seed)
    x = _x(n, seed)
    got = np.asarray(csrc_spmv_t(ad, al, au, ja, x, block_n=32))
    want = np.asarray(ref_spmv_t_ell(ad, al, au, jnp.asarray(ja), x))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


# ------------------------------------------------------- perf-model helpers

def test_vmem_estimate_monotone_in_block():
    assert vmem_bytes(1024, 16, 64) < vmem_bytes(1024, 16, 128)


def test_mxu_utilization_bounds():
    u = mxu_utilization(1024, 16)
    assert 0.0 < u <= 1.0


# ----------------------------------------------------------- dtype coverage

def test_bfloat16_kernel_matches_ref_loosely():
    """TPU-native dtype: bf16 inputs, f32 accumulation inside the kernel
    (preferred_element_type), tolerance scaled to bf16's 8-bit mantissa."""
    import jax.numpy as jnp

    n, w = 64, 4
    ad, al, au, ja = random_csrc_ell(n, w, seed=17)
    x = _x(n, 17)
    to_bf16 = lambda a: jnp.asarray(a, dtype=jnp.bfloat16)
    got = np.asarray(
        csrc_spmv(to_bf16(ad), to_bf16(al), to_bf16(au), ja, to_bf16(x), block_n=32),
        dtype=np.float32,
    )
    want = np.asarray(ref_spmv_ell(ad, al, au, jnp.asarray(ja), x))
    # bf16 has ~2-3 decimal digits; compare with a wide but bounded tol.
    np.testing.assert_allclose(got, want, rtol=0.06, atol=0.1)


def test_wide_rows_and_single_block():
    """w close to n and a single grid step (n == block_n) both work."""
    n, w = 32, 24
    ad, al, au, ja = random_csrc_ell(n, w, seed=19)
    x = _x(n, 19)
    got = np.asarray(csrc_spmv(ad, al, au, ja, x, block_n=32))
    want = np.asarray(ref_spmv_ell(ad, al, au, jnp.asarray(ja), x))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)
