"""L2 graph tests: batched SpMV, CG step, power step, AOT lowering."""

import numpy as np
import jax.numpy as jnp

from compile import model
from compile.kernels.ref import (
    ref_spmv_ell,
    ref_cg_step,
    dense_from_ell,
    random_csrc_ell,
)


def _mat(n=64, w=4, seed=0, **kw):
    return random_csrc_ell(n, w, seed=seed, **kw)


def test_spmv_batch_matches_loop():
    n, w, b = 64, 4, 5
    ad, al, au, ja = _mat(n, w, seed=21)
    xs = np.random.default_rng(21).standard_normal((b, n)).astype(np.float32)
    ys = np.asarray(model.spmv_batch(ad, al, au, ja, xs, block_n=32))
    for i in range(b):
        want = np.asarray(model.spmv(ad, al, au, ja, xs[i], block_n=32))
        np.testing.assert_allclose(ys[i], want, rtol=1e-6)


def test_cg_step_matches_oracle():
    n, w = 64, 4
    ad, al, au, ja = _mat(n, w, seed=33, numeric_symmetric=True)
    rng = np.random.default_rng(33)
    b = rng.standard_normal(n).astype(np.float32)
    x = np.zeros(n, np.float32)
    r = b.copy()
    p = b.copy()
    rs = np.float32(r @ r)
    got = model.cg_step(ad, al, au, ja, x, r, p, rs, block_n=32)
    want = ref_cg_step(ad, al, au, jnp.asarray(ja), x, r, p, rs)
    for g, w_ in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w_), rtol=5e-4, atol=5e-5)


def test_cg_converges_on_spd_system():
    """Run cg_step to convergence on an SPD CSRC matrix: the end-to-end
    proof that the L2 graph is a usable solver building block."""
    n, w = 128, 4
    ad, al, au, ja = _mat(n, w, seed=44, numeric_symmetric=True)
    # Make it diagonally dominant => SPD.
    a = dense_from_ell(ad, al, au, ja)
    ad = ad + np.abs(a).sum(1).astype(np.float32)
    a = dense_from_ell(ad, al, au, ja)
    rng = np.random.default_rng(44)
    xstar = rng.standard_normal(n).astype(np.float32)
    b = (a @ xstar).astype(np.float32)
    x = np.zeros(n, np.float32)
    r = b.copy()
    p = b.copy()
    rs = np.float32(r @ r)
    state = (jnp.asarray(x), jnp.asarray(r), jnp.asarray(p), jnp.asarray(rs))
    rs0 = float(rs)
    for _ in range(2 * n):
        state = model.cg_step(ad, al, au, ja, *state, block_n=32)
        if float(state[3]) < 1e-10 * rs0:
            break
    assert float(state[3]) < 1e-8 * rs0
    np.testing.assert_allclose(np.asarray(state[0]), xstar, rtol=1e-3, atol=1e-3)


def test_power_step_finds_dominant_eigenvalue():
    n, w = 64, 4
    ad, al, au, ja = _mat(n, w, seed=55, numeric_symmetric=True)
    a = dense_from_ell(ad, al, au, ja)
    v = np.ones(n, np.float32) / np.sqrt(n)
    v = jnp.asarray(v)
    for _ in range(300):
        v, lam = model.power_step(ad, al, au, ja, v, block_n=32)
    eigs = np.linalg.eigvalsh(a)
    dominant = eigs[np.argmax(np.abs(eigs))]
    np.testing.assert_allclose(float(lam), dominant, rtol=1e-2)


def test_dense_spmv():
    n = 32
    rng = np.random.default_rng(66)
    a = rng.standard_normal((n, n)).astype(np.float32)
    x = rng.standard_normal(n).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(model.dense_spmv(a, x)), a @ x, rtol=2e-5, atol=2e-5
    )


def test_aot_lowering_all_variants(tmp_path):
    """Every manifest variant lowers to parseable non-empty HLO text."""
    from compile import aot

    for name, fn, n, w, batch in aot.VARIANTS:
        lowered, params, outputs = aot.lower_variant(name, fn, n, w, batch)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert len(text) > 200, name
        assert len(params) > 0 and len(outputs) > 0


def test_spmv_grad_forward_matches_plain():
    n, w = 64, 4
    ad, al, au, ja = _mat(n, w, seed=71)
    x = np.random.default_rng(71).standard_normal(n).astype(np.float32)
    got = np.asarray(model.spmv_grad(ad, al, au, ja, x))
    want = np.asarray(model.spmv(ad, al, au, ja, x))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_spmv_grad_vjp_is_transpose_product():
    """vjp(spmv)(ybar) == A.T @ ybar — the free-transpose property under
    autodiff."""
    import jax

    n, w = 64, 4
    ad, al, au, ja = _mat(n, w, seed=72)
    rng = np.random.default_rng(72)
    x = rng.standard_normal(n).astype(np.float32)
    ybar = rng.standard_normal(n).astype(np.float32)
    _, vjp = jax.vjp(lambda v: model.spmv_grad(ad, al, au, ja, v), x)
    (xbar,) = vjp(ybar)
    a = dense_from_ell(ad, al, au, ja)
    np.testing.assert_allclose(np.asarray(xbar), a.T @ ybar, rtol=2e-4, atol=2e-4)


def test_quadratic_form_grad_is_symmetrized_product():
    n, w = 64, 4
    ad, al, au, ja = _mat(n, w, seed=73)
    x = np.random.default_rng(73).standard_normal(n).astype(np.float32)
    g = np.asarray(model.quadratic_form_grad(ad, al, au, ja, x))
    a = dense_from_ell(ad, al, au, ja)
    want = 0.5 * (a + a.T) @ x
    np.testing.assert_allclose(g, want, rtol=2e-4, atol=2e-4)
