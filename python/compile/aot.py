"""AOT bridge: lower every L2 graph to HLO text for the rust runtime.

HLO *text* is the interchange format, never ``.serialize()``: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):  python -m compile.aot --out ../artifacts

Emits one ``<name>.hlo.txt`` per model variant plus ``manifest.json``
describing parameter shapes, so the rust runtime can size its literals
without re-deriving conventions.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# (name, fn, n, w, batch) — one compiled executable per variant, like a
# serving engine shipping one engine per (model, shape) configuration.
VARIANTS = [
    ("spmv_n256_w8", model.spmv, 256, 8, None),
    ("spmv_n1024_w16", model.spmv, 1024, 16, None),
    ("spmv_t_n256_w8", model.spmv_t, 256, 8, None),
    ("spmv_batch8_n256_w8", model.spmv_batch, 256, 8, 8),
    ("cg_step_n256_w8", None, 256, 8, None),     # special-cased below
    ("power_step_n256_w8", None, 256, 8, None),  # special-cased below
    ("dense_spmv_n256", None, 256, 0, None),     # special-cased below
    ("grad_quadform_n256_w8", None, 256, 8, None),  # special-cased below
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(name, fn, n, w, batch):
    f32 = jax.numpy.float32
    if name.startswith("dense_spmv"):
        a = jax.ShapeDtypeStruct((n, n), f32)
        x = jax.ShapeDtypeStruct((n,), f32)
        lowered = jax.jit(model.dense_spmv).lower(a, x)
        params = [("a", [n, n], "f32"), ("x", [n], "f32")]
        outputs = [("y", [n], "f32")]
        return lowered, params, outputs
    ad, al, au, ja, x = model.make_example_args(n, w, batch)
    mat_params = [
        ("ad", [n], "f32"),
        ("al", [n, w], "f32"),
        ("au", [n, w], "f32"),
        ("ja", [n, w], "i32"),
    ]
    if name.startswith("cg_step"):
        vec = jax.ShapeDtypeStruct((n,), f32)
        scal = jax.ShapeDtypeStruct((), f32)
        lowered = jax.jit(model.cg_step).lower(ad, al, au, ja, vec, vec, vec, scal)
        params = mat_params + [
            ("x", [n], "f32"), ("r", [n], "f32"), ("p", [n], "f32"), ("rs", [], "f32"),
        ]
        outputs = [("x", [n], "f32"), ("r", [n], "f32"), ("p", [n], "f32"), ("rs", [], "f32")]
        return lowered, params, outputs
    if name.startswith("power_step"):
        vec = jax.ShapeDtypeStruct((n,), f32)
        lowered = jax.jit(model.power_step).lower(ad, al, au, ja, vec)
        params = mat_params + [("v", [n], "f32")]
        outputs = [("v", [n], "f32"), ("rayleigh", [], "f32")]
        return lowered, params, outputs
    if name.startswith("grad_quadform"):
        vec = jax.ShapeDtypeStruct((n,), f32)
        lowered = jax.jit(model.quadratic_form_grad).lower(ad, al, au, ja, vec)
        params = mat_params + [("x", [n], "f32")]
        outputs = [("g", [n], "f32")]
        return lowered, params, outputs
    lowered = jax.jit(fn).lower(ad, al, au, ja, x)
    xshape = [batch, n] if batch else [n]
    params = mat_params + [("x", xshape, "f32")]
    outputs = [("y", xshape, "f32")]
    return lowered, params, outputs


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"format": "hlo-text", "return_tuple": True, "entries": []}
    for name, fn, n, w, batch in VARIANTS:
        lowered, params, outputs = lower_variant(name, fn, n, w, batch)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "n": n,
                "w": w,
                "batch": batch,
                "params": [{"name": p, "shape": s, "dtype": d} for p, s, d in params],
                "outputs": [{"name": p, "shape": s, "dtype": d} for p, s, d in outputs],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
