"""L1 — Pallas kernel: structurally-symmetric SpMV in the CSRC-ELL layout.

The paper's CSRC format stores, for a structurally symmetric n x n matrix A
with nnz non-zeros, the diagonal ``ad(n)``, the strict lower triangle
row-wise in ``al(k)`` and the matching upper-triangle transposes in
``au(k)``, sharing one index structure ``ja(k)``, k = (nnz - n) / 2.  One
sweep computes both ``y_i += a_ij x_j`` and ``y_j += a_ji x_i``.

TPU adaptation (DESIGN.md §Hardware-Adaptation): rows are padded to a fixed
width ``w`` (ELL layout) so every array is a dense tile streamable
HBM->VMEM with a BlockSpec:

    ad : f32[n]        diagonal
    al : f32[n, w]     lower values, zero-padded
    au : f32[n, w]     upper-transpose values, zero-padded
    ja : i32[n, w]     column indices; padding slots hold the row's own
                       index (their al/au are 0, so they contribute nothing)

The *scatter* of upper contributions — the very race the paper fights on
multi-core — is reformulated as a one-hot matmul so it runs on the MXU:
each row-block produces a private length-n partial vector (the TPU analogue
of the paper's local-buffers strategy, with "all-in-one" accumulation
folded into the systolic reduction), accumulated across grid steps into the
output block that every step maps to.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU efficiency is estimated analytically in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 64


def _csrc_spmv_kernel(ad_ref, al_ref, au_ref, ja_ref, x_ref, y_ref, *, bn, w, n):
    """One grid step: rows [i*bn, (i+1)*bn) of the CSRC-ELL matrix."""
    i = pl.program_id(0)

    # The output BlockSpec maps every grid step to the full vector, so we
    # zero it exactly once and accumulate partial vectors afterwards.
    @pl.when(i == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    ad = ad_ref[...]  # (bn,)
    al = al_ref[...]  # (bn, w)
    au = au_ref[...]  # (bn, w)
    ja = ja_ref[...]  # (bn, w) int32
    x = x_ref[...]    # (n,)

    xi = jax.lax.dynamic_slice(x, (i * bn,), (bn,))

    # Row-local part: y_i += ad_i * x_i + sum_k al[i,k] * x[ja[i,k]].
    gathered = x[ja]                                     # (bn, w) VMEM gather
    row_vals = ad * xi + jnp.sum(al * gathered, axis=1)  # (bn,)

    # Upper scatter y[ja[i,k]] += au[i,k] * x_i as a one-hot matmul:
    # c[1, bn*w] @ onehot[bn*w, n] -> partial[n] on the MXU. Padding slots
    # point at the row itself with au == 0, contributing nothing.
    c = (au * xi[:, None]).reshape(1, bn * w)
    onehot = (ja.reshape(bn * w, 1) == jnp.arange(n, dtype=ja.dtype)[None, :])
    partial = jnp.dot(
        c, onehot.astype(c.dtype), preferred_element_type=jnp.float32
    )[0].astype(y_ref.dtype)

    y = y_ref[...] + partial
    block = jax.lax.dynamic_slice(y, (i * bn,), (bn,)) + row_vals
    y_ref[...] = jax.lax.dynamic_update_slice(y, block, (i * bn,))


@functools.partial(jax.jit, static_argnames=("block_n",))
def csrc_spmv(ad, al, au, ja, x, *, block_n: int = DEFAULT_BLOCK_N):
    """y = A @ x for a CSRC-ELL structurally symmetric matrix.

    All of ``ad, x`` are ``f32[n]``; ``al, au`` are ``f32[n, w]``; ``ja`` is
    ``i32[n, w]``. ``n`` must be divisible by ``block_n`` (pad the matrix,
    not the kernel).
    """
    n, w = al.shape
    if n % block_n:
        raise ValueError(f"n={n} not divisible by block_n={block_n}")
    bn = block_n
    grid = (n // bn,)
    kernel = functools.partial(_csrc_spmv_kernel, bn=bn, w=w, n=n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),        # ad
            pl.BlockSpec((bn, w), lambda i: (i, 0)),    # al
            pl.BlockSpec((bn, w), lambda i: (i, 0)),    # au
            pl.BlockSpec((bn, w), lambda i: (i, 0)),    # ja
            pl.BlockSpec((n,), lambda i: (0,)),         # x (resident)
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),   # y (accumulated)
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(ad, al, au, ja, x)


def csrc_spmv_t(ad, al, au, ja, x, *, block_n: int = DEFAULT_BLOCK_N):
    """y = A.T @ x — the paper's §5 point: swap ``al`` and ``au``, done."""
    return csrc_spmv(ad, au, al, ja, x, block_n=block_n)


def vmem_bytes(n: int, w: int, bn: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one grid step (DESIGN.md §Perf).

    ad block + al/au/ja blocks + resident x + resident y + the one-hot tile
    (bn*w, n) that feeds the MXU.
    """
    block = bn * dtype_bytes + 3 * bn * w * dtype_bytes
    resident = 2 * n * dtype_bytes
    onehot = bn * w * n * dtype_bytes
    return block + resident + onehot


def mxu_utilization(n: int, w: int) -> float:
    """Fraction of one-hot matmul MACs that are useful (non-padding).

    The scatter matmul performs (n*w) * n MACs but only nnz_strict = n*w_eff
    are useful; with a one-hot operand exactly one MAC per (row, slot) lands
    on a non-zero. Utilization = useful MACs / issued MACs = 1/n per slot,
    i.e. the scatter is bandwidth-bound, not MXU-bound — recorded honestly
    in EXPERIMENTS.md §Perf along with the blocked-column refinement that
    raises it to 1/(n/bn).
    """
    return 1.0 / float(n)
