"""Pure-jnp / numpy oracles for the CSRC-ELL SpMV kernel.

Everything here is the *correctness ground truth*: no Pallas, no clever
layouts. ``ref_spmv_ell`` is the direct semantic statement of CSRC
(diagonal + lower gather + upper scatter); ``dense_from_ell`` reconstructs
the dense matrix so kernels can additionally be checked against a plain
matmul.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def ref_spmv_ell(ad, al, au, ja, x):
    """y = A @ x, straight from the CSRC definition (jnp, no Pallas)."""
    n, w = al.shape
    lower = jnp.sum(al * x[ja], axis=1)
    contrib = (au * x[:, None]).reshape(-1)
    upper = jnp.zeros(n, dtype=x.dtype).at[ja.reshape(-1)].add(contrib)
    return ad * x + lower + upper


def ref_spmv_t_ell(ad, al, au, ja, x):
    """y = A.T @ x: swap the roles of al and au."""
    return ref_spmv_ell(ad, au, al, ja, x)


def dense_from_ell(ad, al, au, ja):
    """Reconstruct the dense matrix A from its CSRC-ELL arrays (numpy)."""
    ad, al, au, ja = map(np.asarray, (ad, al, au, ja))
    n, w = al.shape
    a = np.zeros((n, n), dtype=al.dtype)
    a[np.arange(n), np.arange(n)] = ad
    for i in range(n):
        for k in range(w):
            j = int(ja[i, k])
            a[i, j] += al[i, k]  # lower entry a_ij
            a[j, i] += au[i, k]  # its structural mirror a_ji
    return a


def random_csrc_ell(
    n: int,
    w: int,
    seed: int = 0,
    dtype=np.float32,
    numeric_symmetric: bool = False,
    density: float = 1.0,
):
    """Seeded random structurally-symmetric matrix in CSRC-ELL form.

    Each row i holds up to ``w`` strict-lower entries with column indices
    drawn without replacement from [0, i). Padding slots carry ja == i and
    al == au == 0, matching the kernel's convention. ``density`` < 1 leaves
    a random fraction of slots padded, exercising ragged rows.
    """
    rng = np.random.default_rng(seed)
    ad = rng.standard_normal(n).astype(dtype) + np.asarray(4.0, dtype)  # well-conditioned
    al = np.zeros((n, w), dtype=dtype)
    au = np.zeros((n, w), dtype=dtype)
    ja = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, w))
    for i in range(1, n):
        avail = min(i, w)
        k = int(rng.binomial(avail, density)) if density < 1.0 else avail
        if k == 0:
            continue
        cols = rng.choice(i, size=k, replace=False).astype(np.int32)
        ja[i, :k] = np.sort(cols)
        al[i, :k] = rng.standard_normal(k).astype(dtype)
        au[i, :k] = al[i, :k] if numeric_symmetric else rng.standard_normal(k).astype(dtype)
    return ad, al, au, ja


def ref_cg_step(ad, al, au, ja, x, r, p, rs):
    """One (unpreconditioned) CG iteration on the CSRC matrix — oracle for
    the L2 ``cg_step`` graph."""
    ap = ref_spmv_ell(ad, al, au, ja, p)
    alpha = rs / jnp.dot(p, ap)
    x = x + alpha * p
    r = r - alpha * ap
    rs_new = jnp.dot(r, r)
    p = r + (rs_new / rs) * p
    return x, r, p, rs_new
