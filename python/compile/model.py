"""L2 — the jax compute graphs the rust coordinator executes via PJRT.

Each public function here is a pure jax function over fixed shapes that
calls the L1 Pallas kernel (``kernels.csrc_spmv``). ``aot.py`` lowers each
one to HLO *text* and drops it in ``artifacts/`` together with a manifest;
the rust ``runtime/`` module loads, compiles and executes them. Python is
never on the request path.

Shapes are static per artifact (one compiled executable per model variant,
exactly like a serving engine shipping one engine per configuration).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.csrc_spmv import csrc_spmv, DEFAULT_BLOCK_N


def spmv(ad, al, au, ja, x, *, block_n: int = DEFAULT_BLOCK_N):
    """y = A @ x via the Pallas CSRC-ELL kernel."""
    return csrc_spmv(ad, al, au, ja, x, block_n=block_n)


def spmv_t(ad, al, au, ja, x, *, block_n: int = DEFAULT_BLOCK_N):
    """y = A.T @ x — swap al/au (the CSRC free-transpose property)."""
    return csrc_spmv(ad, au, al, ja, x, block_n=block_n)


def spmv_batch(ad, al, au, ja, xs, *, block_n: int = DEFAULT_BLOCK_N):
    """Y[b] = A @ X[b] for a batch of source vectors (coordinator batching).

    Lowered with ``lax.map`` so the batch loop stays a single compiled
    while-loop in HLO rather than b unrolled kernel bodies.
    """
    return jax.lax.map(
        lambda x: csrc_spmv(ad, al, au, ja, x, block_n=block_n), xs
    )


def cg_step(ad, al, au, ja, x, r, p, rs, *, block_n: int = DEFAULT_BLOCK_N):
    """One unpreconditioned conjugate-gradient iteration.

    State is (x, r, p, rs) with rs = <r, r>. The single SpMV per iteration
    is the Pallas kernel — this is the downstream workload the paper's §4
    benchmark models (1000 products ~ PCG/GMRES solve).
    """
    ap = csrc_spmv(ad, al, au, ja, p, block_n=block_n)
    denom = jnp.dot(p, ap)
    alpha = rs / denom
    x = x + alpha * p
    r = r - alpha * ap
    rs_new = jnp.dot(r, r)
    beta = rs_new / rs
    p = r + beta * p
    return x, r, p, rs_new


def power_step(ad, al, au, ja, v, *, block_n: int = DEFAULT_BLOCK_N):
    """One normalized power iteration: returns (v', rayleigh)."""
    av = csrc_spmv(ad, al, au, ja, v, block_n=block_n)
    norm = jnp.sqrt(jnp.dot(av, av))
    v_new = av / norm
    rayleigh = jnp.dot(v, av)
    return v_new, rayleigh


@jax.custom_vjp
def spmv_grad(ad, al, au, ja, x):
    """Differentiable y = A @ x (w.r.t. x).

    The custom VJP is the paper's §5 point made executable: the cotangent
    pull-back is Aᵀ·ȳ, which CSRC computes by *swapping al and au* — no
    transpose materialization, same kernel, same cost.
    """
    return csrc_spmv(ad, al, au, ja, x)


def _spmv_fwd(ad, al, au, ja, x):
    return csrc_spmv(ad, al, au, ja, x), (ad, al, au, ja)


def _spmv_bwd(res, ybar):
    ad, al, au, ja = res
    # Aᵀ ȳ via the al/au swap; matrix arrays get no cotangent (treated as
    # constants of the compiled artifact).
    xbar = csrc_spmv(ad, au, al, ja, ybar)
    return (None, None, None, None, xbar)


spmv_grad.defvjp(_spmv_fwd, _spmv_bwd)


def quadratic_form_grad(ad, al, au, ja, x):
    """∇ₓ ½ xᵀAx = ½(A + Aᵀ)x — exercises the custom VJP under jax.grad;
    lowered as an artifact so rust can run gradient steps."""
    return jax.grad(lambda v: 0.5 * jnp.dot(v, spmv_grad(ad, al, au, ja, v)))(x)


def dense_spmv(a, x):
    """Dense y = A @ x baseline (pure XLA matmul, no kernel) — used by the
    harness to sanity-check the runtime and as the dense_1000 analogue."""
    return jnp.dot(a, x, preferred_element_type=jnp.float32)


def make_example_args(n: int, w: int, batch: int | None = None):
    """ShapeDtypeStructs for lowering a given (n, w) variant."""
    f32 = jnp.float32
    i32 = jnp.int32
    ad = jax.ShapeDtypeStruct((n,), f32)
    al = jax.ShapeDtypeStruct((n, w), f32)
    au = jax.ShapeDtypeStruct((n, w), f32)
    ja = jax.ShapeDtypeStruct((n, w), i32)
    if batch is None:
        x = jax.ShapeDtypeStruct((n,), f32)
    else:
        x = jax.ShapeDtypeStruct((batch, n), f32)
    return ad, al, au, ja, x
