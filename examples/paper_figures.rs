//! Regenerate every paper table/figure in one run (the quick suite) and
//! print the paper-shape checks: does CSRC beat CSR sequentially, is
//! `effective` the most stable local-buffers method, does colorful win
//! only on the smallest-bandwidth matrices?
//!
//! Run: `cargo run --release --example paper_figures [-- smoke|quick|full]`

use csrc_spmv::harness::{self, figures, Report};
use csrc_spmv::simulator::MachineConfig;
use csrc_spmv::util::stats;

fn main() {
    let which = std::env::args().nth(1).unwrap_or_else(|| "smoke".into());
    let suite = match which.as_str() {
        "full" => harness::full_suite(),
        "quick" => harness::quick_suite(),
        _ => harness::smoke_suite(),
    };
    println!("suite: {} ({} matrices)\n", which, suite.len());
    let report = Report::new(Some(std::path::Path::new("results"))).unwrap();

    // Table 1 (the suite itself).
    report
        .table("table1", "Table 1 — dataset", &["matrix", "sym", "n", "nnz", "nnz/n", "ws (KB)"],
               &figures::table1(&suite))
        .unwrap();

    // Fig. 4 — cache behaviour.
    let fig4 = figures::fig4(&suite);
    report
        .table("fig4", "Fig. 4 — % L2 / TLB misses (Wolfdale model)",
               &["matrix", "csrc L2%", "csr L2%", "csrc TLB%", "csr TLB%"], &fig4)
        .unwrap();
    let avg = |rows: &Vec<Vec<String>>, c: usize| {
        stats::mean(&rows.iter().map(|r| r[c].parse::<f64>().unwrap()).collect::<Vec<_>>())
            .expect("figure rows are non-empty")
    };
    println!(
        "[check] avg L2 miss%: csrc {:.2} vs csr {:.2} (paper: csrc not worse)\n",
        avg(&fig4, 1),
        avg(&fig4, 2)
    );

    // Fig. 5 — sequential Mflop/s.
    let fig5 = figures::fig5(&suite);
    report
        .table("fig5", "Fig. 5 — sequential Mflop/s",
               &["matrix", "csrc Mflop/s", "csr Mflop/s", "speedup"], &fig5)
        .unwrap();
    let ratios: Vec<f64> = fig5.iter().map(|r| r[3].parse().unwrap()).collect();
    println!(
        "[check] CSRC vs CSR sequential: geomean time ratio {:.3} (>1 means CSRC faster; paper: CSRC wins)\n",
        stats::geomean(&ratios).expect("figure rows are non-empty")
    );

    // Figs. 6/7 — colorful.
    let fig6 = figures::fig6(&suite);
    report
        .table("fig6", "Fig. 6 — colorful vs best local-buffers",
               &["matrix", "col wolf2", "lb wolf2", "col bloom4", "lb bloom4", "winner"], &fig6)
        .unwrap();
    let colorful_wins: Vec<&str> =
        fig6.iter().filter(|r| r[5] == "colorful").map(|r| r[0].as_str()).collect();
    println!("[check] colorful wins on: {colorful_wins:?} (paper: only smallest-bandwidth matrices)\n");
    report
        .table("fig7", "Fig. 7 — colorful speedups",
               &["matrix", "colors", "wolf 2t", "bloom 2t", "bloom 4t"], &figures::fig7(&suite))
        .unwrap();

    // Figs. 8/9 — local buffers.
    for (name, cfg) in [("fig8", MachineConfig::wolfdale()), ("fig9", MachineConfig::bloomfield())] {
        let headers = figures::fig89_headers(&cfg);
        let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let rows = figures::fig89(&suite, &cfg);
        report.table(name, &format!("{name} — local-buffers speedups ({})", cfg.name), &h, &rows).unwrap();
        if name == "fig9" {
            // Stability check: how often is `effective` the best method at 2t?
            let mut eff_best = 0usize;
            for r in &rows {
                let vals: Vec<f64> = (1..5).map(|c| r[c].parse().unwrap()).collect();
                let best = vals.iter().cloned().fold(f64::MIN, f64::max);
                if (vals[2] - best).abs() < 1e-9 {
                    eff_best += 1;
                }
            }
            println!(
                "\n[check] `effective` best on {}/{} matrices at 2 threads (paper: ~78-93%)\n",
                eff_best,
                rows.len()
            );
        }
    }

    // Table 2 — accumulation overheads.
    let headers = figures::table2_headers();
    let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    report
        .table("table2", "Table 2 — init+accumulation overhead (ms)", &h, &figures::table2(&suite))
        .unwrap();

    println!("paper_figures OK — results under results/");
}
