//! Serving-style driver: the coordinator accepts a stream of matvec
//! requests against registered matrices, batches per matrix, routes small
//! matrices to the sequential sweep and large ones to the *autotuned*
//! parallel engine (`EngineKind::Auto` with `sweep_threads`: each
//! registered matrix is trialed across engines × the thread ladder at
//! registration and served by its measured winner at the winning thread
//! count), and reports throughput + latency percentiles. Workers track
//! each matrix's served rate; if it drifts below half the decision's
//! recorded rate, a background re-tune upgrades the decision off the
//! request path.
//!
//! Run: `cargo run --release --example matvec_service [-- requests]`

use csrc_spmv::coordinator::{MatvecService, ServiceConfig};
use csrc_spmv::gen;
use csrc_spmv::parallel::EngineKind;
use csrc_spmv::sparse::Csrc;
use csrc_spmv::tuner::TrialBudget;
use csrc_spmv::util::{Rng, Timer};
use std::sync::Arc;

fn main() {
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(256);

    let mut cfg = ServiceConfig { workers: 2, ..Default::default() };
    cfg.route.min_parallel_n = 20_000; // small -> sequential, large -> tuned
    cfg.route.threads = 2;
    cfg.route.parallel_kind = EngineKind::Auto; // measured per-matrix pick
    cfg.route.sweep_threads = true; // …including the thread count
    cfg.tune_budget = TrialBudget { runs: 1, products: 4 };
    let svc = MatvecService::start(cfg);

    // Register a model zoo: small 2-D, medium 3-D, large 3-D.
    let small = Arc::new(Csrc::from_coo(&gen::poisson_2d_quad(40, 0.2, 1)).unwrap());
    let medium = Arc::new(Csrc::from_coo(&gen::poisson_3d_hex(20, 0.3, 2)).unwrap());
    let large = Arc::new(Csrc::from_coo(&gen::poisson_3d_hex(32, 0.0, 3)).unwrap());
    println!(
        "registered: small n={}, medium n={}, large n={}",
        small.n, medium.n, large.n
    );
    let matrices = [("small", small), ("medium", medium), ("large", large)];
    for (k, m) in &matrices {
        svc.register(k, m.clone());
    }

    // Fire a mixed request stream (closed-loop batches of 32 in flight).
    let mut rng = Rng::new(5);
    let t = Timer::start();
    let mut pending = Vec::new();
    let mut done = 0usize;
    let mut checked = 0usize;
    for i in 0..requests {
        let (key, m) = &matrices[i % 3];
        let x: Vec<f64> = (0..m.n).map(|_| rng.normal()).collect();
        pending.push(((*key, m.clone(), x.clone()), svc.submit(key, x)));
        if pending.len() >= 32 {
            for ((_k, m, x), rx) in pending.drain(..) {
                let y = rx.recv().expect("service alive").expect("product ok");
                done += 1;
                // Spot-check 1 in 8 responses against the sequential sweep.
                if done % 8 == 0 {
                    let mut want = vec![0.0; m.n];
                    m.spmv_into_zeroed(&x, &mut want);
                    let ok = y
                        .iter()
                        .zip(&want)
                        .all(|(a, b)| (a - b).abs() < 1e-9 * (1.0 + b.abs()));
                    assert!(ok, "response mismatch");
                    checked += 1;
                }
            }
        }
    }
    for ((_k, _m, _x), rx) in pending.drain(..) {
        rx.recv().expect("service alive").expect("product ok");
        done += 1;
    }
    let dt = t.elapsed_s();
    let s = svc.stats();
    println!(
        "served {done}/{requests} requests in {dt:.3}s -> {:.0} req/s ({checked} spot-checked)",
        done as f64 / dt
    );
    println!(
        "batches formed: {} (avg batch {:.2}); latency mean {:.0}us p50 {:.0}us p99 {:.0}us",
        s.batches,
        s.completed as f64 / s.batches.max(1) as f64,
        s.mean_latency_us,
        s.p99_latency_us / 2.0, // bucket upper bound -> midpoint-ish
        s.p99_latency_us
    );
    println!(
        "plans built: {} ({:.2} ms analysis total) — shared across all workers",
        s.plan_builds,
        s.plan_build_seconds * 1e3
    );
    for ((key, label), (_, p)) in s.auto_choices.iter().zip(&s.chosen_threads) {
        println!("autotuned {key} -> {label} @ {p} threads");
    }
    println!(
        "tuning: {} measured runs, {:.1} ms total, {} decision-cache hits, \
         {} drift events, {} re-tunes",
        s.tunes,
        s.tune_seconds * 1e3,
        s.decision_hits,
        s.drift_events,
        s.retunes
    );
    svc.shutdown();
    println!("matvec_service OK");
}
