//! Quickstart: build a structurally symmetric matrix, store it in CSRC,
//! multiply sequentially and in parallel, and use the free transpose.
//!
//! Run: `cargo run --release --example quickstart`

use csrc_spmv::parallel::{build_engine, AccumMethod, EngineKind};
use csrc_spmv::plan::PlanBuilder;
use csrc_spmv::sparse::{Coo, Csr, Csrc, LinOp, SpmvKernel};
use csrc_spmv::util::Rng;
use std::sync::Arc;

fn main() {
    // 1. Assemble a matrix (any structurally symmetric pattern works;
    //    here: a random FEM-like pattern with ~5 off-diagonals per row).
    let n = 10_000;
    let mut rng = Rng::new(42);
    let coo = Coo::random_structurally_symmetric(n, 5, /*numeric_sym=*/ false, &mut rng);

    // 2. Compress. CSRC stores the diagonal, the lower triangle row-wise
    //    and the upper triangle column-wise behind one index structure —
    //    roughly half the index memory of CSR (§2 of the paper).
    let a = Arc::new(Csrc::from_coo(&coo).expect("pattern is structurally symmetric"));
    let csr = Csr::from_coo(&coo);
    println!(
        "CSRC working set {} KB vs CSR {} KB ({} nnz)",
        a.working_set_bytes() / 1024,
        csr.working_set_bytes() / 1024,
        a.nnz()
    );

    // 3. Sequential product (Fig. 2a of the paper).
    let x: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
    let mut y_seq = vec![0.0; n];
    a.spmv_into_zeroed(&x, &mut y_seq);

    // 4. Analyze once: one full SpmvPlan (partition, effective ranges,
    //    intervals, coloring) that every engine below borrows — the
    //    analysis/execution split the coordinator caches per matrix.
    let kernel: Arc<dyn SpmvKernel> = a.clone();
    let plan = Arc::new(PlanBuilder::all(/*threads=*/ 4).build(kernel.as_ref()));
    println!("plan built in {:.2} ms, shared by all engines", plan.stats.total_s * 1e3);

    // 5. Parallel product with the paper's best-overall strategy:
    //    local buffers + effective accumulation, nnz-balanced partition.
    let mut engine = build_engine(
        EngineKind::LocalBuffers(AccumMethod::Effective),
        kernel.clone(),
        plan.clone(),
    );
    let mut y_par = vec![0.0; n];
    engine.spmv(&x, &mut y_par);
    let max_diff = y_seq
        .iter()
        .zip(&y_par)
        .map(|(s, p)| (s - p).abs())
        .fold(0.0, f64::max);
    println!("parallel engine `{}` max |Δ| vs sequential = {max_diff:.3e}", engine.name());
    assert!(max_diff < 1e-10);

    // 6. Transpose product for free — swap the roles of al and au (§5).
    let mut yt = vec![0.0; n];
    a.apply_t(&x, &mut yt).expect("CSRC supports the transpose product");
    println!("Aᵀx computed at the same cost as Ax (no transpose pass)");

    // 7. The colorful alternative (§3.2): conflict-free row classes —
    //    same kernel, same shared plan, different executor.
    let mut colorful = build_engine(EngineKind::Colorful, kernel.clone(), plan.clone());
    let mut y_col = vec![0.0; n];
    colorful.spmv(&x, &mut y_col);
    let max_diff_col = y_seq
        .iter()
        .zip(&y_col)
        .map(|(s, p)| (s - p).abs())
        .fold(0.0, f64::max);
    println!("{} max |Δ| vs sequential = {max_diff_col:.3e}", colorful.name());
    assert!(max_diff_col < 1e-10);

    println!("quickstart OK");
}
