//! Time-stepping FEM under serving (ISSUE 10's tentpole workload): one
//! registration — one tune, one plan, one ordering — then every step
//! re-assembles the time-dependent coefficients in parallel (atomic
//! scatter vs. colored batches, raced once like any tuned choice),
//! patches the served matrix in place with `update_values`, and
//! re-solves. The tuner never runs again; only the values generation
//! moves.
//!
//! Run: `cargo run --release --example timestep [-- nx [steps [threads]]]`

#![allow(clippy::field_reassign_with_default)]

use csrc_spmv::coordinator::{MatvecService, ServiceConfig};
use csrc_spmv::gen::{Assembler, Mesh2d};
use csrc_spmv::parallel::EngineKind;
use csrc_spmv::reorder::ReorderPolicy;
use csrc_spmv::tuner::TrialBudget;
use csrc_spmv::solver::{self, Jacobi};
use csrc_spmv::sparse::LinOp;
use csrc_spmv::util::Timer;
use std::sync::Arc;

/// CG's view of the serving stack: every A·p inside the solve is a
/// request through the batcher/worker path, so the example stresses
/// exactly what production traffic would.
struct Served<'a> {
    svc: &'a MatvecService,
    key: &'a str,
    n: usize,
}

impl LinOp for Served<'_> {
    fn dim(&self) -> usize {
        self.n
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        let r = self.svc.call(self.key, x.to_vec()).expect("served product");
        y.copy_from_slice(&r);
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let nx: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(24);
    let steps: u32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let threads: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);

    // --- assemble once: the pattern (and everything derived from it) ---
    let t = Timer::start();
    let mesh = Mesh2d::quads(nx, nx);
    let mut asm = Assembler::new(mesh, 0.0).expect("structured mesh assembles");
    let n = asm.matrix().n;
    println!(
        "assembled {nx}×{nx} quad mesh -> n={n}, nnz={}, {} element colors, {:.2}s",
        asm.matrix().nnz(),
        asm.num_colors(),
        t.elapsed_s()
    );

    // --- register once: tune, plan, reorder — never again ---------------
    let mut cfg = ServiceConfig::default();
    cfg.route.parallel_kind = EngineKind::Auto;
    cfg.route.threads = threads;
    cfg.route.min_parallel_n = 1;
    cfg.route.reorder = ReorderPolicy::Always;
    cfg.tune_budget = TrialBudget::smoke();
    cfg.drift_fraction = 0.0;
    let svc = MatvecService::start(cfg);
    svc.register("heat", Arc::new(asm.matrix().clone()));
    let _ = svc.call("heat", vec![1.0; n]).expect("warm the tune, plan, and ordering");
    let s0 = svc.stats();
    assert_eq!(s0.tunes, 1, "registration tunes exactly once");

    // --- time loop: re-assemble, patch in place, re-solve ----------------
    let b: Vec<f64> = (0..n).map(|i| ((i as f64) * 0.11).sin()).collect();
    let t = Timer::start();
    for step in 1..=steps {
        let time = 0.25 * step as f64;
        let next = asm.assemble(time, threads);
        svc.update_values("heat", &next).expect("pattern never changes");
        svc.record_assembly(matches!(
            asm.choice(),
            Some(csrc_spmv::gen::AssemblyKind::Colored)
        ));
        let jac = Jacobi::new(&next).expect("CSRC exposes its diagonal");
        let op = Served { svc: &svc, key: "heat", n };
        let r = solver::cg(&op, &b, Some(&jac), 1e-9, 2000);
        assert!(r.converged, "step {step}: CG stalled at {}", r.residual);
        println!(
            "step {step:>3}: t={time:.2}, {} CG iterations, residual {:.2e}",
            r.iterations, r.residual
        );
    }
    let loop_s = t.elapsed_s();

    // --- the contract the whole PR exists for ----------------------------
    let s = svc.stats();
    assert_eq!(s.tunes, s0.tunes, "updates must never re-tune");
    assert_eq!(s.plan_builds, s0.plan_builds, "plans survive value updates");
    assert_eq!(s.rcm_builds, s0.rcm_builds, "orderings survive value updates");
    assert_eq!(s.value_updates, steps as u64);
    let choice = match asm.choice() {
        Some(k) => k.label(),
        None => "unraced",
    };
    println!(
        "{steps} steps in {loop_s:.2}s: value_updates={}, tunes={}, \
         plan_builds={}, rcm_builds={}, assembly={choice}",
        s.value_updates, s.tunes, s.plan_builds, s.rcm_builds
    );
    svc.shutdown();
    println!("timestep OK");
}
