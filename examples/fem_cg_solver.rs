//! End-to-end driver (the EXPERIMENTS.md workload): assemble a real 3-D
//! finite-element system, solve it with preconditioned CG running on the
//! parallel CSRC engine, and log the residual curve — the workload the
//! paper's 1000-product benchmark stands in for (§4).
//!
//! Run: `cargo run --release --example fem_cg_solver [-- nx [threads]]`
//!
//! Exercises every L3 layer: gen (mesh + assembly) → sparse (CSRC) →
//! partition/parallel (effective local buffers) → solver (Jacobi-PCG) →
//! metrics, plus BiCG on a convection variant to exercise Aᵀx.

use csrc_spmv::gen;
use csrc_spmv::metrics;
use csrc_spmv::parallel::{build_engine_auto, AccumMethod, EngineKind};
use csrc_spmv::solver::{self, Jacobi, ParallelLinOp};
use csrc_spmv::sparse::{Csrc, LinOp};
use csrc_spmv::util::{Rng, Timer};
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let nx: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(28);
    let threads: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);

    // --- assemble ------------------------------------------------------
    let t = Timer::start();
    let coo = gen::poisson_3d_hex(nx, 0.0, 7);
    let a = Arc::new(Csrc::from_coo(&coo).expect("FEM pattern is structurally symmetric"));
    println!(
        "assembled {}³ hex mesh -> n={}, nnz={}, ws={} KB, hbw={} in {:.2}s",
        nx,
        a.n,
        a.nnz(),
        a.working_set_bytes() / 1024,
        a.half_bandwidth(),
        t.elapsed_s()
    );
    assert!(a.numeric_symmetric, "pure diffusion must assemble symmetric");

    // --- manufactured solution -----------------------------------------
    let n = a.n;
    let mut rng = Rng::new(1);
    let xstar: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut b = vec![0.0; n];
    a.apply(&xstar, &mut b);

    // --- parallel engine + Jacobi-PCG ------------------------------------
    let mut engine =
        build_engine_auto(EngineKind::LocalBuffers(AccumMethod::Effective), a.clone(), threads);
    let jac = Jacobi::new(a.as_ref()).expect("CSRC exposes its diagonal");
    let op = ParallelLinOp::new(n, engine.as_mut());
    let t = Timer::start();
    let result = solver::cg(&op, &b, Some(&jac), 1e-10, 5000);
    let solve_s = t.elapsed_s();
    assert!(result.converged, "PCG failed: residual {}", result.residual);
    let err = result
        .x
        .iter()
        .zip(&xstar)
        .map(|(g, w)| (g - w).abs())
        .fold(0.0, f64::max);
    println!(
        "PCG({} threads) converged in {} iterations, {:.2}s, max |x - x*| = {err:.2e}",
        threads, result.iterations, solve_s
    );

    // Residual curve (every ~10th iteration).
    println!("residual curve (iteration, ||r||/||b||):");
    for (i, r) in result.history.iter().enumerate() {
        if i % (result.history.len() / 10).max(1) == 0 || i + 1 == result.history.len() {
            println!("  {i:>5}  {r:.3e}");
        }
    }

    // --- throughput of the hot path --------------------------------------
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.01).cos()).collect();
    let mut y = vec![0.0; n];
    let products = 200;
    let per = metrics::median_of_runs(3, products, || engine.spmv(&x, &mut y));
    println!(
        "hot path: {:.3} ms/product, {:.1} Mflop/s over {} products (median of 3 runs)",
        per * 1e3,
        metrics::mflops(a.flops(), per),
        products
    );

    // --- BiCG on a convection-perturbed (non-symmetric) variant ----------
    let coo_c = gen::poisson_3d_hex(nx.min(16), 0.5, 9);
    let ac = Csrc::from_coo(&coo_c).unwrap();
    assert!(!ac.numeric_symmetric);
    let bc: Vec<f64> = (0..ac.n).map(|_| rng.normal()).collect();
    let t = Timer::start();
    let r = solver::bicg(&ac, &bc, 1e-8, 4000).expect("CSRC supports the transpose product");
    println!(
        "BiCG (uses the free CSRC transpose every iteration): {} in {} its, {:.2}s",
        if r.converged { "converged" } else { "no convergence" },
        r.iterations,
        t.elapsed_s()
    );
    println!("fem_cg_solver OK");
}
