//! Ablations beyond the paper's figures (DESIGN.md §7):
//!
//! * atomics baseline vs local buffers vs colorful (the §3 claim that
//!   atomic primitives are too costly),
//! * plan reuse: cold plan-build + product vs cached-plan product (the
//!   analysis/execution split the coordinator exploits),
//! * nnz-balanced vs naive row partitioning (the §3.1 claim),
//! * coloring order and the §5 stride-capped future-work idea,
//! * BCSR blocking baseline vs CSRC (the §1.1 related-work contrast),
//! * parallel engine overhead as a function of matrix size,
//! * autotuned engine pick vs the fixed `local-buffers/effective`
//!   default across the generated suite (the tuner's reason to exist),
//! * swept (engine × nthreads) pick vs the engine tuned at a fixed
//!   thread count (the §4 scalability claim: several matrices peak
//!   below the core count),
//! * learned cost model vs the hand-written heuristic on held-out
//!   matrices (the cross-matrix claim behind `tuner::model`),
//! * blocked multi-vector panels (`spmv_multi`) vs k serial products on
//!   a FEM-like matrix (DESIGN.md §11) — separate `BENCH_spmm.json`,
//! * instrumentation overhead: products with the phase spans disabled,
//!   metrics-enabled, and traced (DESIGN.md §12) — separate
//!   `BENCH_obs.json`,
//! * shard scaling: end-to-end served rate and halo volume through the
//!   sharded front vs shard count (DESIGN.md §13) — separate
//!   `BENCH_shard.json`,
//! * fault-injection overhead: disarmed chaos gates (one relaxed load
//!   each) bounded against a served product (DESIGN.md §14) — separate
//!   `BENCH_faults.json`,
//! * in-place `update_values` vs full re-registration per time step,
//!   with the raced atomic-vs-colored assembly variants (DESIGN.md §15)
//!   — separate `BENCH_update.json`.
//!
//! Results land on stdout *and* in `results/ablations.json` (the SpMM
//! and obs ablations write their own `results/BENCH_*.json`).

use csrc_spmv::graph::{greedy_coloring, stride_capped_coloring, ConflictGraph, Ordering};
use csrc_spmv::harness::smoke_suite;
use csrc_spmv::parallel::{
    build_engine, build_engine_auto, AccumMethod, ColorfulEngine, EngineKind,
};
use csrc_spmv::partition;
use csrc_spmv::plan::PlanBuilder;
use csrc_spmv::sparse::{Bcsr, Coo, Csrc, SpmvKernel};
use csrc_spmv::util::bench::Bench;
use csrc_spmv::util::Rng;
use std::sync::Arc;

fn main() {
    let mut b = Bench::new("ablations");

    // --- atomic vs buffered vs colorful (one medium matrix) -------------
    let e = smoke_suite().into_iter().find(|e| e.name == "poisson3Da").unwrap();
    let a = Arc::new(e.build_csrc());
    let n = a.n;
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.01).sin()).collect();
    let mut y = vec![0.0; n];
    for kind in [
        EngineKind::Sequential,
        EngineKind::LocalBuffers(AccumMethod::Effective),
        EngineKind::Colorful,
        EngineKind::Atomic,
    ] {
        let mut engine = build_engine_auto(kind, a.clone(), 2);
        b.run(&format!("engine/{}", kind.label()), || engine.spmv(&x, &mut y));
    }

    // --- plan reuse: cold analysis+product vs cached-plan product --------
    // Both legs run the same engine (pool construction is identical
    // either way and must not be attributed to analysis); the cold leg
    // additionally redoes the plan analysis per product, as an uncached
    // service would.
    {
        let kind = EngineKind::LocalBuffers(AccumMethod::Interval);
        let kernel: Arc<dyn SpmvKernel> = a.clone();
        let plan = Arc::new(PlanBuilder::for_kind(2, kind).build(kernel.as_ref()));
        let mut engine = build_engine(kind, kernel.clone(), plan.clone());
        let t_cold = b.run("plan/cold-build+spmv", || {
            std::hint::black_box(PlanBuilder::for_kind(2, kind).build(kernel.as_ref()));
            engine.spmv(&x, &mut y);
        });
        let t_warm = b.run("plan/cached-spmv", || engine.spmv(&x, &mut y));
        b.record("plan/build-ms", plan.stats.total_s * 1e3, "ms");
        b.record("plan/cold-over-warm", t_cold / t_warm, "x");
    }

    // --- partitioning: nnz-balanced vs rowwise ---------------------------
    let part_nnz = partition::nnz_balanced(a.as_ref(), 4);
    let part_rows = partition::rowwise_even(a.n, 4);
    let work = |part: &partition::RowPartition| -> f64 {
        let works: Vec<f64> = (0..4)
            .map(|t| part.block(t).map(|i| 1.0 + 2.0 * a.row_range(i).len() as f64).sum())
            .collect();
        let max = works.iter().cloned().fold(0.0, f64::max);
        let avg = works.iter().sum::<f64>() / 4.0;
        max / avg // imbalance factor (1.0 = perfect)
    };
    b.record("partition/nnz-balanced-imbalance", work(&part_nnz), "max/avg");
    b.record("partition/rowwise-imbalance", work(&part_rows), "max/avg");

    // --- coloring orders + stride cap ------------------------------------
    let g = ConflictGraph::build(a.as_ref());
    let natural = greedy_coloring(&g, Ordering::Natural);
    let ldf = greedy_coloring(&g, Ordering::LargestDegreeFirst);
    b.record("coloring/natural-colors", natural.num_colors() as f64, "colors");
    b.record("coloring/ldf-colors", ldf.num_colors() as f64, "colors");
    for cap in [64usize, 1024, usize::MAX / 2] {
        let capped = stride_capped_coloring(&g, cap);
        b.record(
            &format!("coloring/stride-cap-{cap}"),
            capped.num_colors() as f64,
            "colors",
        );
        let mut engine = ColorfulEngine::with_coloring(a.clone(), 2, capped);
        use csrc_spmv::parallel::ParallelSpmv;
        b.run(&format!("colorful/stride-cap-{cap}"), || engine.spmv(&x, &mut y));
    }

    // --- BCSR blocking baseline ------------------------------------------
    let csr = a.to_csr();
    for (r, c) in [(1, 1), (2, 2), (4, 4)] {
        let blocked = Bcsr::from_csr(&csr, r, c);
        b.record(
            &format!("bcsr/{r}x{c}-fill"),
            blocked.fill_ratio(csr.nnz()),
            "fill ratio",
        );
        b.run(&format!("bcsr/{r}x{c}-spmv"), || blocked.spmv(&x, &mut y));
    }
    b.run("csr/spmv", || csr.spmv(&x, &mut y));

    // --- RCM reordering (paper §1/§4.2: band structure matters) ----------
    {
        use csrc_spmv::graph::{permute, reverse_cuthill_mckee};
        let mut rng = Rng::new(21);
        let band = Csrc::from_coo(&Coo::banded(4000, 3, true, &mut rng)).unwrap();
        let shuffled = permute(&band, &rng.permutation(4000));
        b.record("rcm/shuffled-hbw", shuffled.half_bandwidth() as f64, "rows");
        let t_rcm = b.run("rcm/compute-ordering", || {
            std::hint::black_box(reverse_cuthill_mckee(&shuffled));
        });
        let _ = t_rcm;
        let restored = permute(&shuffled, &reverse_cuthill_mckee(&shuffled));
        b.record("rcm/restored-hbw", restored.half_bandwidth() as f64, "rows");
        // Color counts before/after: bandwidth drives the colorful method.
        let g_before = ConflictGraph::build(&shuffled);
        let g_after = ConflictGraph::build(&restored);
        b.record(
            "rcm/colors-before",
            greedy_coloring(&g_before, Ordering::Natural).num_colors() as f64,
            "colors",
        );
        b.record(
            "rcm/colors-after",
            greedy_coloring(&g_after, Ordering::Natural).num_colors() as f64,
            "colors",
        );
        // SpMV throughput before/after reordering.
        let xs: Vec<f64> = (0..4000).map(|i| i as f64 * 1e-3).collect();
        let mut ys = vec![0.0; 4000];
        b.run("rcm/spmv-shuffled", || shuffled.spmv_into_zeroed(&xs, &mut ys));
        b.run("rcm/spmv-restored", || restored.spmv_into_zeroed(&xs, &mut ys));
    }

    // --- windowed vs full buffers + reorder vs identity (ISSUE 4) --------
    // The two coupled bandwidth levers measured separately and together:
    // (a) windowed local buffers vs the full-length p·n layout — the
    //     windowed engine must zero/accumulate strictly fewer bytes
    //     (reported below) on any matrix, and measurably fewer on a
    //     banded one;
    // (b) RCM reordering vs identity on a shuffled banded FEM-style
    //     matrix — half-bandwidth and working-set reduction, with a
    //     correctness check across every engine on the reordered
    //     operator.
    {
        use csrc_spmv::parallel::{LocalBuffersEngine, ParallelSpmv};
        use csrc_spmv::reorder::{rcm, Permutation, ReorderedEngine};
        let mut rng = Rng::new(29);
        let p = 4usize;
        let n = 6000usize;
        let band = Csrc::from_coo(&Coo::banded(n, 4, false, &mut rng)).unwrap();
        let shuffle = Permutation::from_new_to_old(rng.permutation(n)).unwrap();
        let shuffled = Arc::new(band.permuted(&shuffle));
        let xs: Vec<f64> = (0..n).map(|i| (i as f64 * 1e-3).sin()).collect();
        let mut ys = vec![0.0; n];
        let mut oracle = vec![0.0; n];
        shuffled.spmv_into_zeroed(&xs, &mut oracle);
        let close = |y: &[f64]| {
            y.iter().zip(&oracle).all(|(a, b)| (a - b).abs() <= 1e-9 * (1.0 + b.abs()))
        };

        // (a) windowed vs full on the shuffled matrix and on the RCM
        // restoration (windowing pays most once the band is tight).
        let perm = Arc::new(rcm(shuffled.as_ref()));
        let restored = Arc::new(shuffled.permuted(&perm));
        b.record("reorder/hbw-identity", shuffled.half_bandwidth() as f64, "rows");
        b.record("reorder/hbw-rcm", restored.half_bandwidth() as f64, "rows");
        for (tag, m) in [("shuffled", &shuffled), ("rcm", &restored)] {
            let plan = Arc::new(PlanBuilder::all(p).build(m.as_ref()));
            b.record(
                &format!("reorder/ws-parallel-{tag}-kb"),
                (m.working_set_bytes_parallel(&plan) / 1024) as f64,
                "KB",
            );
            for method in [AccumMethod::AllInOne, AccumMethod::Effective] {
                let mut windowed =
                    LocalBuffersEngine::with_plan(m.clone(), plan.clone(), method);
                let mut full = LocalBuffersEngine::with_plan_windowed(
                    m.clone(),
                    plan.clone(),
                    method,
                    false,
                );
                assert!(
                    windowed.bytes_zeroed_per_product() <= full.bytes_zeroed_per_product()
                        && windowed.buffer_bytes() < full.buffer_bytes(),
                    "windowed buffers must shrink the byte footprint"
                );
                b.record(
                    &format!("windowed/{tag}-{}-bytes-zeroed", method.label()),
                    windowed.bytes_zeroed_per_product() as f64,
                    "bytes",
                );
                b.record(
                    &format!("windowed/{tag}-{}-bytes-zeroed-full", method.label()),
                    full.bytes_zeroed_per_product() as f64,
                    "bytes",
                );
                b.record(
                    &format!("windowed/{tag}-{}-buffer-bytes", method.label()),
                    windowed.buffer_bytes() as f64,
                    "bytes",
                );
                b.record(
                    &format!("windowed/{tag}-{}-buffer-bytes-full", method.label()),
                    full.buffer_bytes() as f64,
                    "bytes",
                );
                let t_w = b.run(&format!("windowed/{tag}-{}-windowed", method.label()), || {
                    windowed.spmv(&xs, &mut ys)
                });
                let t_f = b.run(&format!("windowed/{tag}-{}-full", method.label()), || {
                    full.spmv(&xs, &mut ys)
                });
                b.record(
                    &format!("windowed/{tag}-{}-speedup", method.label()),
                    t_f / t_w,
                    "x",
                );
            }
        }

        // (b) reorder-vs-identity end-to-end: every engine over the RCM
        // operator (permute in / un-permute out) must match the plain
        // sequential oracle — no correctness regression — and the
        // reordered effective engine is timed against the identity one.
        let rplan = Arc::new(PlanBuilder::all(p).build(restored.as_ref()));
        let iplan = Arc::new(PlanBuilder::all(p).build(shuffled.as_ref()));
        for kind in EngineKind::all() {
            let mut engine = ReorderedEngine::new(
                build_engine(kind, restored.clone(), rplan.clone()),
                perm.clone(),
            );
            let mut y = vec![f64::NAN; n];
            engine.spmv(&xs, &mut y);
            assert!(close(&y), "reordered {} diverges from the oracle", kind.label());
        }
        let kind = EngineKind::LocalBuffers(AccumMethod::Effective);
        let mut identity = build_engine(kind, shuffled.clone(), iplan);
        let mut reordered_eng =
            ReorderedEngine::new(build_engine(kind, restored.clone(), rplan), perm.clone());
        let t_id = b.run("reorder/spmv-identity-effective", || identity.spmv(&xs, &mut ys));
        let t_rcm =
            b.run("reorder/spmv-rcm-effective", || reordered_eng.spmv(&xs, &mut ys));
        b.record("reorder/speedup-rcm-over-identity", t_id / t_rcm, "x");
    }

    // --- distributed subdomain layer (paper §2.1/§5) ----------------------
    {
        use csrc_spmv::coordinator::DistributedMatrix;
        use csrc_spmv::sparse::Csr;
        let g = Csr::from_coo(&csrc_spmv::gen::poisson_3d_hex(16, 0.0, 23));
        let xs: Vec<f64> = (0..g.nrows).map(|i| (i as f64 * 0.1).sin()).collect();
        let mut ys = vec![0.0; g.nrows];
        b.run("distributed/global-spmv", || g.spmv(&xs, &mut ys));
        for nsub in [2usize, 4, 8] {
            let mut dm = DistributedMatrix::from_global(&g, nsub);
            b.record(
                &format!("distributed/halo-volume-{nsub}sub"),
                dm.halo_volume() as f64,
                "doubles",
            );
            b.run(&format!("distributed/spmv-{nsub}sub"), || dm.spmv(&xs, &mut ys));
        }
    }

    // --- autotuned pick vs the fixed default ------------------------------
    // The tuner trials every candidate per matrix (cheap budget) and the
    // bench then re-measures its pick against the router's fixed
    // `local-buffers/effective` default: the tuned rate must match or
    // beat the fixed one (it can pick `effective` itself, so "within
    // noise" is the floor, not a hope).
    {
        use csrc_spmv::tuner::{self, TrialBudget};
        for e in smoke_suite() {
            let m = Arc::new(e.build_csrc());
            let kernel: Arc<dyn SpmvKernel> = m.clone();
            let plan = Arc::new(PlanBuilder::all(2).build(kernel.as_ref()));
            let d = tuner::tune(&kernel, &plan, &TrialBudget { runs: 1, products: 2 });
            let nn = m.n;
            let xs: Vec<f64> = (0..nn).map(|i| (i as f64 * 0.001).sin()).collect();
            let mut ys = vec![0.0; nn];
            let mut tuned = build_engine(d.kind, kernel.clone(), plan.clone());
            let mut fixed = build_engine(
                EngineKind::LocalBuffers(AccumMethod::Effective),
                kernel.clone(),
                plan.clone(),
            );
            let t_tuned = b.run(&format!("autotuned/{}-tuned({})", e.name, d.kind.label()), || {
                tuned.spmv(&xs, &mut ys)
            });
            let t_fixed =
                b.run(&format!("autotuned/{}-fixed(local-buffers-effective)", e.name), || {
                    fixed.spmv(&xs, &mut ys)
                });
            b.record(
                &format!("autotuned/{}-tuned-mflops", e.name),
                csrc_spmv::metrics::mflops(m.flops(), t_tuned),
                "Mflop/s",
            );
            b.record(
                &format!("autotuned/{}-fixed-mflops", e.name),
                csrc_spmv::metrics::mflops(m.flops(), t_fixed),
                "Mflop/s",
            );
            b.record(&format!("autotuned/{}-speedup", e.name), t_fixed / t_tuned, "x");
        }
    }

    // --- swept (engine × p) vs fixed-p autotune ---------------------------
    // The §4 scalability curves: some matrices peak below the core
    // count, so tuning the engine at one fixed p leaves rate on the
    // table. Sweep the thread ladder, then re-measure the swept pick
    // against the pick tuned at the fixed maximum p. The swept pick can
    // land on the same (engine, p) — "within noise" is the floor.
    {
        use csrc_spmv::plan::PlanCache;
        use csrc_spmv::tuner::{self, TrialBudget};
        let max_p = 4usize;
        let budget = TrialBudget { runs: 1, products: 2 };
        for e in smoke_suite().into_iter().take(3) {
            let m = Arc::new(e.build_csrc());
            let kernel: Arc<dyn SpmvKernel> = m.clone();
            let plans = PlanCache::new();
            let mut plan_for = tuner::cached_plan_provider(&plans, e.name, &kernel);
            let swept = tuner::sweep(&kernel, &tuner::thread_ladder(max_p), &budget, &mut plan_for);
            let fixed_plan = plan_for(max_p);
            let fixed = tuner::tune(&kernel, &fixed_plan, &budget);
            let swept_plan = plan_for(swept.nthreads);
            let nn = m.n;
            let xs: Vec<f64> = (0..nn).map(|i| (i as f64 * 0.001).sin()).collect();
            let mut ys = vec![0.0; nn];
            let mut eng_swept = build_engine(swept.kind, kernel.clone(), swept_plan);
            let mut eng_fixed = build_engine(fixed.kind, kernel.clone(), fixed_plan);
            let t_swept = b.run(
                &format!("sweep/{}-swept({}@{}t)", e.name, swept.kind.label(), swept.nthreads),
                || eng_swept.spmv(&xs, &mut ys),
            );
            let t_fixed = b.run(
                &format!("sweep/{}-fixed({}@{max_p}t)", e.name, fixed.kind.label()),
                || eng_fixed.spmv(&xs, &mut ys),
            );
            b.record(&format!("sweep/{}-chosen-threads", e.name), swept.nthreads as f64, "threads");
            b.record(&format!("sweep/{}-speedup-over-fixed-p", e.name), t_fixed / t_swept, "x");
        }
    }

    // --- learned cost model vs the hand-written heuristic -----------------
    // Tune a small generated corpus, train the model on most of it, and
    // compare cold-start picks on the held-out matrices: the model's
    // pick and the heuristic's are each re-measured next to the
    // measured winner. The JSON report records both rates plus whether
    // the model matched the measured winner.
    {
        use csrc_spmv::reorder::ReorderPolicy;
        use csrc_spmv::tuner::{self, TrialBudget};
        let budget = TrialBudget { runs: 1, products: 2 };
        let p = 2usize;
        let mut corpus_decisions = Vec::new();
        let mut held_out = Vec::new();
        for seed in 0..8u64 {
            let mut rng = Rng::new(100 + seed);
            let coo = if seed % 2 == 0 {
                Coo::random_structurally_symmetric(1200 + 200 * seed as usize, 4, false, &mut rng)
            } else {
                Coo::banded(1500 + 150 * seed as usize, 3, false, &mut rng)
            };
            let m = Arc::new(Csrc::from_coo(&coo).unwrap());
            let kernel: Arc<dyn SpmvKernel> = m.clone();
            let plan = Arc::new(PlanBuilder::all(p).build(kernel.as_ref()));
            let d = tuner::tune(&kernel, &plan, &budget);
            if seed < 6 {
                corpus_decisions.push(d);
            } else {
                held_out.push((m, kernel, plan, d));
            }
        }
        let model = tuner::CostModel::train(&tuner::model::rows_from_decisions(&corpus_decisions))
            .expect("six measured decisions train");
        for (i, (m, kernel, plan, d)) in held_out.iter().enumerate() {
            let heur_kind = tuner::cost_model(&d.features);
            // A declining model is recorded as exactly that — silently
            // substituting the heuristic's pick would fabricate 1.0×
            // "model" speedups out of the heuristic racing itself.
            let Some(model_kind) = model.predict(&d.features, ReorderPolicy::Never).map(|p| p.kind)
            else {
                b.record(&format!("model/heldout{i}-model-declined"), 1.0, "bool");
                continue;
            };
            let nn = m.n;
            let xs: Vec<f64> = (0..nn).map(|i| (i as f64 * 0.001).sin()).collect();
            let mut ys = vec![0.0; nn];
            let mut em = build_engine(model_kind, kernel.clone(), plan.clone());
            let mut eh = build_engine(heur_kind, kernel.clone(), plan.clone());
            let t_model = b.run(
                &format!("model/heldout{i}-model-pick({})", model_kind.label()),
                || em.spmv(&xs, &mut ys),
            );
            let t_heur = b.run(
                &format!("model/heldout{i}-heuristic-pick({})", heur_kind.label()),
                || eh.spmv(&xs, &mut ys),
            );
            b.record(
                &format!("model/heldout{i}-model-matches-measured-winner"),
                (model_kind == d.kind) as usize as f64,
                "bool",
            );
            b.record(
                &format!("model/heldout{i}-speedup-model-over-heuristic"),
                t_heur / t_model,
                "x",
            );
        }
    }

    // --- engine overhead vs size ------------------------------------------
    for nn in [512usize, 4096, 32768] {
        let mut rng = Rng::new(7);
        let small = Arc::new(
            Csrc::from_coo(&Coo::random_structurally_symmetric(nn, 4, false, &mut rng)).unwrap(),
        );
        let xs: Vec<f64> = (0..nn).map(|i| i as f64 * 1e-4).collect();
        let mut ys = vec![0.0; nn];
        let mut seq = build_engine_auto(EngineKind::Sequential, small.clone(), 1);
        let t_seq = b.run(&format!("overhead/n{nn}-seq"), || seq.spmv(&xs, &mut ys));
        let mut par = build_engine_auto(EngineKind::LocalBuffers(AccumMethod::Effective), small, 2);
        let t_par = b.run(&format!("overhead/n{nn}-effective-2t"), || par.spmv(&xs, &mut ys));
        b.record(&format!("overhead/n{nn}-ratio"), t_par / t_seq, "par/seq (1 core)");
    }

    b.finish_json(std::path::Path::new("results/ablations.json")).expect("write json report");

    // --- SpMM: blocked panels vs k serial products (ISSUE 6) --------------
    // One blocked `spmv_multi` sweep reads A (values + column indices)
    // once for all k vectors, where k serial calls stream the matrix k
    // times — so on a FEM-like banded matrix whose working set dwarfs
    // the cache, the blocked product should win for the wider panels.
    // Correctness first: every engine's k=4 panel against the serial
    // oracle, column by column. Results land in their own report,
    // `results/BENCH_spmm.json`.
    {
        let mut sb = Bench::new("spmm");
        let mut rng = Rng::new(31);
        let n = 20_000usize;
        let fem = Arc::new(Csrc::from_coo(&Coo::banded(n, 6, false, &mut rng)).unwrap());
        let kernel: Arc<dyn SpmvKernel> = fem.clone();
        let plan = Arc::new(PlanBuilder::all(2).build(kernel.as_ref()));
        sb.record("spmm/ws-kb", (fem.working_set_bytes() / 1024) as f64, "KB");
        let kmax = 8usize;
        let cols: Vec<Vec<f64>> = (0..kmax)
            .map(|c| (0..n).map(|i| ((i + 11 * c) as f64 * 1e-3).sin()).collect())
            .collect();
        let oracle: Vec<Vec<f64>> = cols
            .iter()
            .map(|x| {
                let mut y = vec![0.0; n];
                fem.spmv_into_zeroed(x, &mut y);
                y
            })
            .collect();
        let pack = |k: usize| {
            let mut xp = vec![0.0; n * k];
            for (c, col) in cols.iter().take(k).enumerate() {
                for (i, &v) in col.iter().enumerate() {
                    xp[i * k + c] = v;
                }
            }
            xp
        };
        for kind in EngineKind::all() {
            let mut engine = build_engine(kind, kernel.clone(), plan.clone());
            let k = 4usize;
            let xp = pack(k);
            let mut yp = vec![f64::NAN; n * k];
            engine.spmv_multi(&xp, &mut yp, k);
            for (c, want) in oracle.iter().take(k).enumerate() {
                assert!(
                    (0..n).all(|i| (yp[i * k + c] - want[i]).abs() <= 1e-9 * (1.0 + want[i].abs())),
                    "spmm {} column {c} diverges from the serial oracle",
                    kind.label()
                );
            }
        }
        let kind = EngineKind::LocalBuffers(AccumMethod::Effective);
        let mut engine = build_engine(kind, kernel.clone(), plan.clone());
        let mut y = vec![0.0; n];
        for k in [1usize, 2, 4, 8] {
            let t_serial = sb.run(&format!("spmm/k{k}-serial"), || {
                for x in cols.iter().take(k) {
                    engine.spmv(x, &mut y);
                }
            });
            let xp = pack(k);
            let mut yp = vec![0.0; n * k];
            let t_blocked =
                sb.run(&format!("spmm/k{k}-blocked"), || engine.spmv_multi(&xp, &mut yp, k));
            sb.record(&format!("spmm/k{k}-speedup"), t_serial / t_blocked, "x");
        }
        sb.finish_json(std::path::Path::new("results/BENCH_spmm.json"))
            .expect("write spmm json report");
    }

    // --- instrumentation overhead (ISSUE 7) -------------------------------
    // The phase spans are compiled in unconditionally and gated on two
    // relaxed atomic loads, so a product served with instrumentation
    // disabled must stay within 2% of an uninstrumented build. Rather
    // than racing two timed loops (noise swamps a 2% bound in CI), the
    // bound is measured directly: disabled `obs::phase()` costs
    // nanoseconds, a product crosses it a counted handful of times, and
    // their product over the product time is the worst-case overhead.
    // Metrics-enabled and traced runs are timed alongside for the real
    // cost of turning each dial. Own report: results/BENCH_obs.json.
    {
        use csrc_spmv::obs::{self, Phase};
        let mut ob = Bench::new("obs");
        obs::set_metrics_enabled(false);
        let t_gate = ob.run("obs/phase-guard-disabled", || {
            std::hint::black_box(obs::phase(Phase::Sweep));
        });
        let mut rng = Rng::new(41);
        let n = 10_000usize;
        let fem = Arc::new(Csrc::from_coo(&Coo::banded(n, 5, false, &mut rng)).unwrap());
        let kernel: Arc<dyn SpmvKernel> = fem.clone();
        let plan = Arc::new(PlanBuilder::all(2).build(kernel.as_ref()));
        let kind = EngineKind::LocalBuffers(AccumMethod::Effective);
        let mut engine = build_engine(kind, kernel, plan);
        let xs: Vec<f64> = (0..n).map(|i| (i as f64 * 1e-3).sin()).collect();
        let mut ys = vec![0.0; n];
        let t_off = ob.run("obs/spmv-disabled", || engine.spmv(&xs, &mut ys));
        // One instrumented product counts the spans a product crosses.
        obs::set_metrics_enabled(true);
        obs::reset_phases();
        engine.spmv(&xs, &mut ys);
        let spans: u64 = obs::phase_totals().iter().map(|t| t.calls).sum();
        let t_on = ob.run("obs/spmv-metrics", || engine.spmv(&xs, &mut ys));
        obs::start_trace();
        let t_tr = ob.run("obs/spmv-traced", || engine.spmv(&xs, &mut ys));
        let events = obs::stop_trace();
        obs::set_metrics_enabled(false);
        ob.record("obs/spans-per-product", spans as f64, "spans");
        ob.record("obs/trace-events", events.len() as f64, "events");
        ob.record("obs/trace-dropped", obs::trace_dropped() as f64, "begin events");
        ob.record("obs/metrics-over-disabled", t_on / t_off, "x");
        ob.record("obs/trace-over-disabled", t_tr / t_off, "x");
        let overhead_pct = 100.0 * spans as f64 * t_gate / t_off;
        ob.record("obs/disabled-overhead-pct", overhead_pct, "% of product");
        assert!(
            overhead_pct < 2.0,
            "disabled instrumentation must stay within 2% of a product \
             ({spans} spans x {t_gate:.3e}s gate vs {t_off:.3e}s product)"
        );
        ob.finish_json(std::path::Path::new("results/BENCH_obs.json"))
            .expect("write obs json report");
    }

    // --- shard scaling (ISSUE 8) ------------------------------------------
    // The sharded front pays a halo (ghost values re-gathered per
    // product, growing with the shard count) and scatter/gather routing
    // to buy shard-local tuning and bounded queues. This measures that
    // trade directly on a FEM-like banded matrix: end-to-end served
    // rate (single-vector and a k=4 panel) and halo volume per shard
    // count, correctness asserted against the sequential kernel. Own
    // report: results/BENCH_shard.json.
    {
        use csrc_spmv::coordinator::{ShardConfig, ShardedMatvecService};
        let mut hb = Bench::new("shard");
        let mut rng = Rng::new(51);
        let n = 20_000usize;
        let fem = Arc::new(Csrc::from_coo(&Coo::banded(n, 6, false, &mut rng)).unwrap());
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 1e-3).sin()).collect();
        let mut want = vec![0.0; n];
        fem.spmv_into_zeroed(&x, &mut want);
        let k = 4usize;
        let xp: Vec<f64> = (0..n * k).map(|i| ((i % n) as f64 * 1e-3).cos()).collect();
        for nshards in [1usize, 2, 4, 7] {
            let svc = ShardedMatvecService::start(ShardConfig {
                nshards,
                ..ShardConfig::default()
            });
            svc.register("fem", fem.clone());
            let got = svc.spmv("fem", &x).expect("sharded product");
            assert!(
                (0..n).all(|i| (got[i] - want[i]).abs() <= 1e-9 * (1.0 + want[i].abs())),
                "{nshards}-shard product diverges from the sequential kernel"
            );
            let t1 = hb.run(&format!("shard/s{nshards}-spmv"), || {
                std::hint::black_box(svc.spmv("fem", &x).expect("sharded product"));
            });
            let tk = hb.run(&format!("shard/s{nshards}-spmv-multi-k{k}"), || {
                std::hint::black_box(svc.spmv_multi("fem", &xp, k).expect("sharded panel"));
            });
            hb.record(
                &format!("shard/s{nshards}-mflops"),
                fem.flops() as f64 / t1.max(1e-12) / 1e6,
                "Mflop/s served",
            );
            hb.record(
                &format!("shard/s{nshards}-panel-mflops-per-vec"),
                fem.flops() as f64 * k as f64 / tk.max(1e-12) / 1e6,
                "Mflop/s/vec served",
            );
            hb.record(&format!("shard/s{nshards}-halo"), svc.halo_doubles(), "doubles/product");
            svc.shutdown();
        }
        hb.finish_json(std::path::Path::new("results/BENCH_shard.json"))
            .expect("write shard json report");
    }

    // --- fault-injection overhead (ISSUE 9) -------------------------------
    // The chaos gates are compiled in unconditionally, exactly like the
    // obs spans: disarmed, each `faults::fire()` is one relaxed load.
    // Same methodology as the obs bound — measure the disarmed gate
    // directly, count how many gates one sharded product crosses (a
    // rate-0 armed spec sends every crossing down the counting path
    // without ever firing), and bound the disarmed overhead by their
    // product over the product time. A rate-0 armed product is timed
    // alongside for the real cost of the armed slow path (one mutex
    // lock per gate). Own report: results/BENCH_faults.json.
    {
        use csrc_spmv::coordinator::{ShardConfig, ShardedMatvecService};
        use csrc_spmv::faults::{self, InjectionPoint};
        let mut fb = Bench::new("faults");
        faults::reset();
        let t_gate = fb.run("faults/fire-gate-disarmed", || {
            std::hint::black_box(faults::fire(InjectionPoint::WorkerPanic));
        });
        let mut rng = Rng::new(61);
        let n = 10_000usize;
        let fem = Arc::new(Csrc::from_coo(&Coo::banded(n, 5, false, &mut rng)).unwrap());
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 1e-3).sin()).collect();
        let mut want = vec![0.0; n];
        fem.spmv_into_zeroed(&x, &mut want);
        let svc = ShardedMatvecService::start(ShardConfig {
            nshards: 2,
            ..ShardConfig::default()
        });
        svc.register("fem", fem.clone());
        let got = svc.spmv("fem", &x).expect("warm product");
        assert!(
            (0..n).all(|i| (got[i] - want[i]).abs() <= 1e-9 * (1.0 + want[i].abs())),
            "sharded product diverges from the sequential kernel"
        );
        let t_off = fb.run("faults/spmv-disarmed", || {
            std::hint::black_box(svc.spmv("fem", &x).expect("disarmed product"));
        });
        // Count the gates a product crosses: arm an empty (all-idle)
        // spec so every `fire()` counts a check and none ever fires.
        faults::configure("").expect("empty chaos spec");
        faults::set_chaos_enabled(true);
        let products = 4u64;
        for _ in 0..products {
            std::hint::black_box(svc.spmv("fem", &x).expect("counted product"));
        }
        let gates = faults::checks_total().div_ceil(products);
        let t_armed = fb.run("faults/spmv-armed-rate0", || {
            std::hint::black_box(svc.spmv("fem", &x).expect("armed product"));
        });
        faults::reset();
        svc.shutdown();
        fb.record("faults/gates-per-product", gates as f64, "gates");
        fb.record("faults/armed-over-disarmed", t_armed / t_off, "x");
        let overhead_pct = 100.0 * gates as f64 * t_gate / t_off;
        fb.record("faults/disarmed-overhead-pct", overhead_pct, "% of product");
        assert!(
            overhead_pct < 2.0,
            "disarmed fault gates must stay within 2% of a product \
             ({gates} gates x {t_gate:.3e}s gate vs {t_off:.3e}s product)"
        );
        fb.finish_json(std::path::Path::new("results/BENCH_faults.json"))
            .expect("write faults json report");
    }

    // --- in-place update vs full re-registration (ISSUE 10) ---------------
    // A time-stepping FEM client re-assembles the same pattern every
    // step. The in-place leg patches values under the served key —
    // plan, RCM ordering, and tuned decision all survive, only the
    // values generation moves. The re-registration leg pays the whole
    // registration pipeline again per step (invalidation, RCM, lazy
    // re-tune on the next product). The raced assembly variants are
    // reported alongside. Own report: results/BENCH_update.json.
    {
        use csrc_spmv::coordinator::{MatvecService, RoutePolicy, ServiceConfig};
        use csrc_spmv::gen::{Assembler, AssemblyKind, Mesh2d};
        use csrc_spmv::reorder::ReorderPolicy;
        use csrc_spmv::tuner::TrialBudget;
        let mut ub = Bench::new("update");
        let mesh = Mesh2d::quads(48, 48);
        let mut asm = Assembler::new(mesh, 0.0).expect("structured mesh assembles");
        let n = asm.matrix().n;
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 1e-3).sin()).collect();
        let race = asm.race(2);
        ub.record("assemble/atomic-s", race.atomic_s, "s");
        ub.record("assemble/colored-s", race.colored_s, "s");
        ub.record("assemble/colors", race.colors as f64, "colors");
        ub.record(
            "assemble/chose-colored",
            matches!(race.chosen, AssemblyKind::Colored) as usize as f64,
            "bool",
        );
        let cfg = ServiceConfig {
            workers: 1,
            route: RoutePolicy {
                parallel_kind: EngineKind::Auto,
                min_parallel_n: 1,
                threads: 2,
                reorder: ReorderPolicy::Always,
                ..Default::default()
            },
            tune_budget: TrialBudget::smoke(),
            drift_fraction: 0.0,
            ..Default::default()
        };
        let svc = MatvecService::start(cfg);
        svc.register("step", Arc::new(asm.matrix().clone()));
        let _ = svc.call("step", x.clone()).expect("warm tune + plan + ordering");
        let mut t = 0.0;
        let t_update = ub.run("update/assemble+update+spmv", || {
            t += 0.1;
            let next = asm.assemble(t, 2);
            svc.update_values("step", &next).expect("pattern never changes");
            std::hint::black_box(svc.call("step", x.clone()).expect("served product"));
        });
        let updates_only = svc.stats();
        let t_rereg = ub.run("update/assemble+reregister+spmv", || {
            t += 0.1;
            let next = asm.assemble(t, 2);
            svc.register("step", Arc::new(next));
            std::hint::black_box(svc.call("step", x.clone()).expect("served product"));
        });
        let s = svc.stats();
        // The legs must have exercised what they claim: the update leg
        // never re-tunes, the re-registration leg re-tunes every step.
        assert_eq!(updates_only.tunes, 1, "in-place updates must not re-tune");
        assert!(
            s.tunes > updates_only.tunes,
            "re-registration must pay the tuner again"
        );
        ub.record("update/value-updates", s.value_updates as f64, "updates");
        ub.record("update/reregister-tunes", (s.tunes - 1) as f64, "tunes");
        ub.record("update/speedup-over-reregister", t_rereg / t_update, "x");
        svc.shutdown();
        ub.finish_json(std::path::Path::new("results/BENCH_update.json"))
            .expect("write update json report");
    }
}
