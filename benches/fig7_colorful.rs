//! Bench: Fig. 7 — colorful speedups on both machine models, plus the
//! cost of the one-time coloring preprocessing (conflict graph build +
//! greedy coloring), which the paper amortizes over 1000 products.

use csrc_spmv::graph::{greedy_coloring, ConflictGraph, Ordering};
use csrc_spmv::harness::smoke_suite;
use csrc_spmv::simulator::{sim_colorful, sim_csrc_sequential, MachineConfig, MachineSim};
use csrc_spmv::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig7_colorful");
    for e in smoke_suite() {
        let m = e.build_csrc();
        // Preprocessing cost.
        b.run(&format!("{}/conflict-graph", e.name), || {
            std::hint::black_box(ConflictGraph::build(&m));
        });
        let g = ConflictGraph::build(&m);
        b.run(&format!("{}/greedy-coloring", e.name), || {
            std::hint::black_box(greedy_coloring(&g, Ordering::Natural));
        });
        let colors = greedy_coloring(&g, Ordering::Natural);
        b.record(&format!("{}/colors", e.name), colors.num_colors() as f64, "colors");
        // Figure numbers.
        for (cfg, p) in [
            (MachineConfig::wolfdale(), 2usize),
            (MachineConfig::bloomfield(), 2),
            (MachineConfig::bloomfield(), 4),
        ] {
            let mut sim = MachineSim::new(cfg.clone());
            let base = sim_csrc_sequential(&mut sim, &m).cycles;
            let mut sim = MachineSim::new(cfg.clone());
            let sp = base / sim_colorful(&mut sim, &m, p, &colors).cycles;
            b.record(&format!("{}/{}-{}t", e.name, cfg.name, p), sp, "x speedup");
        }
    }
    b.finish();
}
