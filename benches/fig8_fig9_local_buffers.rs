//! Bench: Figs. 8 & 9 — local-buffers speedups for all four
//! init/accumulation methods on both machine models, plus real wallclock
//! per method (engine overhead is visible even on one core).

use csrc_spmv::harness::smoke_suite;
use csrc_spmv::parallel::{build_engine_auto, AccumMethod, EngineKind};
use csrc_spmv::simulator::{sim_csrc_sequential, sim_local_buffers, MachineConfig, MachineSim};
use csrc_spmv::util::bench::Bench;
use std::sync::Arc;

fn main() {
    let mut b = Bench::new("fig8_fig9_local_buffers");
    for e in smoke_suite() {
        let a = Arc::new(e.build_csrc());
        let n = a.n;
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.01).cos()).collect();
        let mut y = vec![0.0; n];
        for meth in AccumMethod::all() {
            let mut engine = build_engine_auto(EngineKind::LocalBuffers(meth), a.clone(), 2);
            b.run(&format!("{}/{}-2t-wallclock", e.name, meth.label()), || {
                engine.spmv(&x, &mut y)
            });
        }
        // Simulated figure numbers: Fig. 8 = wolfdale 2t, Fig. 9 = bloomfield 2/4t.
        for (cfg, threads) in [
            (MachineConfig::wolfdale(), vec![2usize]),
            (MachineConfig::bloomfield(), vec![2, 4]),
        ] {
            let mut sim = MachineSim::new(cfg.clone());
            let base = sim_csrc_sequential(&mut sim, &a).cycles;
            for p in threads {
                for meth in AccumMethod::all() {
                    let mut sim = MachineSim::new(cfg.clone());
                    let sp = base / sim_local_buffers(&mut sim, &a, p, meth).cycles;
                    b.record(
                        &format!("{}/{}-{}-{}t", e.name, cfg.name, meth.label(), p),
                        sp,
                        "x speedup",
                    );
                }
            }
        }
    }
    b.finish();
}
