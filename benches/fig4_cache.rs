//! Bench: Fig. 4 — simulated % L2 / TLB misses for CSRC vs CSR on the
//! Wolfdale cache model, plus the wall time of the simulation itself
//! (the simulator is part of the hot path of `csrc figures`).

use csrc_spmv::harness::{figures, smoke_suite};
use csrc_spmv::simulator::{sim_csr_sequential, sim_csrc_sequential, MachineConfig, MachineSim};
use csrc_spmv::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig4_cache");
    for e in smoke_suite() {
        let m = e.build_csrc();
        let csr = m.to_csr();
        // Simulation wall time (one product each).
        b.run(&format!("{}/sim-csrc", e.name), || {
            let mut sim = MachineSim::new(MachineConfig::wolfdale());
            let r = sim_csrc_sequential(&mut sim, &m);
            std::hint::black_box(r.cycles);
        });
        b.run(&format!("{}/sim-csr", e.name), || {
            let mut sim = MachineSim::new(MachineConfig::wolfdale());
            let r = sim_csr_sequential(&mut sim, &csr);
            std::hint::black_box(r.cycles);
        });
        // The figure's numbers.
        let mut sim = MachineSim::new(MachineConfig::wolfdale());
        let rc = sim_csrc_sequential(&mut sim, &m);
        let mut sim = MachineSim::new(MachineConfig::wolfdale());
        let rr = sim_csr_sequential(&mut sim, &csr);
        b.record(&format!("{}/csrc-l2-miss", e.name), rc.misses.outer_miss_pct(), "%");
        b.record(&format!("{}/csr-l2-miss", e.name), rr.misses.outer_miss_pct(), "%");
        b.record(&format!("{}/csrc-tlb-miss", e.name), rc.misses.tlb_miss_pct(), "%");
        b.record(&format!("{}/csr-tlb-miss", e.name), rr.misses.tlb_miss_pct(), "%");
    }
    let _ = figures::products_for(1);
    b.finish();
}
