//! Bench: Fig. 6 — colorful vs the best local-buffers method. Real
//! wallclock per engine (honestly ~flat on this 1-core box) plus the
//! simulated Wolfdale/Bloomfield speedups that reproduce the figure.

use csrc_spmv::graph::{greedy_coloring, ConflictGraph, Ordering};
use csrc_spmv::harness::smoke_suite;
use csrc_spmv::parallel::{build_engine_auto, AccumMethod, EngineKind};
use csrc_spmv::simulator::{sim_colorful, sim_csrc_sequential, sim_local_buffers, MachineConfig, MachineSim};
use csrc_spmv::util::bench::Bench;
use std::sync::Arc;

fn main() {
    let mut b = Bench::new("fig6_colorful_vs_lb");
    for e in smoke_suite() {
        let a = Arc::new(e.build_csrc());
        let n = a.n;
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.01).sin()).collect();
        let mut y = vec![0.0; n];
        // Real engines, 2 threads.
        let mut colorful = build_engine_auto(EngineKind::Colorful, a.clone(), 2);
        b.run(&format!("{}/colorful-2t-wallclock", e.name), || colorful.spmv(&x, &mut y));
        let mut eff =
            build_engine_auto(EngineKind::LocalBuffers(AccumMethod::Effective), a.clone(), 2);
        b.run(&format!("{}/effective-2t-wallclock", e.name), || eff.spmv(&x, &mut y));
        // Simulated figure numbers.
        let wolf = MachineConfig::wolfdale();
        let mut sim = MachineSim::new(wolf.clone());
        let base = sim_csrc_sequential(&mut sim, &a).cycles;
        let g = ConflictGraph::build(a.as_ref());
        let colors = greedy_coloring(&g, Ordering::Natural);
        let mut sim = MachineSim::new(wolf.clone());
        let col = base / sim_colorful(&mut sim, &a, 2, &colors).cycles;
        let best_lb = AccumMethod::all()
            .iter()
            .map(|&meth| {
                let mut sim = MachineSim::new(wolf.clone());
                base / sim_local_buffers(&mut sim, &a, 2, meth).cycles
            })
            .fold(0.0, f64::max);
        b.record(&format!("{}/sim-colorful-wolf2", e.name), col, "x");
        b.record(&format!("{}/sim-best-lb-wolf2", e.name), best_lb, "x");
    }
    b.finish();
}
