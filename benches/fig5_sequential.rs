//! Bench: Fig. 5 — sequential Mflop/s of CSR vs CSRC (plus the symmetric
//! CSRC kernel) on the smoke suite. The paper's relation to hold: CSRC ≥
//! CSR on most matrices (lower load:flop ratio, §4.1).

use csrc_spmv::harness::smoke_suite;
use csrc_spmv::metrics::mflops;
use csrc_spmv::sparse::Csr;
use csrc_spmv::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig5_sequential");
    for e in smoke_suite() {
        let m = e.build_csrc();
        let csr = m.to_csr();
        let n = m.n;
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.001).sin()).collect();
        let mut y = vec![0.0; n];
        let csrc_t = b.run(&format!("{}/csrc", e.name), || m.spmv_into_zeroed(&x, &mut y));
        b.record(&format!("{}/csrc", e.name), mflops(m.flops(), csrc_t), "Mflop/s");
        if m.numeric_symmetric {
            let sym_t = b.run(&format!("{}/csrc-sym", e.name), || {
                y.fill(0.0);
                m.spmv_sym(&x, &mut y);
            });
            b.record(&format!("{}/csrc-sym", e.name), mflops(m.flops(), sym_t), "Mflop/s");
        }
        let csr_t = b.run(&format!("{}/csr", e.name), || csr.spmv(&x, &mut y));
        b.record(&format!("{}/csr", e.name), mflops(csr.flops(), csr_t), "Mflop/s");
        b.record(&format!("{}/csrc-vs-csr", e.name), csr_t / csrc_t, "x speedup");
        let _ = Csr::from_coo; // keep the import honest
    }
    b.finish();
}
