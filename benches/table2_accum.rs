//! Bench: Table 2 — the init+accumulation overhead of each local-buffers
//! method. Real measurement: `LocalBuffersEngine::last_overhead_ns` (max
//! across threads, like the paper's "maximum running time among all
//! threads"), averaged over products; simulated: the Table 2 harness.

use csrc_spmv::harness::smoke_suite;
use csrc_spmv::parallel::{AccumMethod, LocalBuffersEngine, ParallelSpmv};
use csrc_spmv::util::bench::Bench;
use std::sync::Arc;

fn main() {
    let mut b = Bench::new("table2_accum");
    for e in smoke_suite() {
        let a = Arc::new(e.build_csrc());
        let n = a.n;
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.02).sin()).collect();
        let mut y = vec![0.0; n];
        for meth in AccumMethod::all() {
            for p in [2usize, 4] {
                let mut engine = LocalBuffersEngine::new(a.clone(), p, meth);
                let mut total_ns = 0u64;
                let reps = 20;
                for _ in 0..reps {
                    engine.spmv(&x, &mut y);
                    total_ns += engine.last_overhead_ns;
                }
                b.record(
                    &format!("{}/{}-{}t-max-thread-overhead", e.name, meth.label(), p),
                    total_ns as f64 / reps as f64 / 1e3,
                    "us/product",
                );
            }
        }
    }
    b.finish();
}
