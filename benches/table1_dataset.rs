//! Bench: Table 1 — dataset construction + CSRC compression throughput
//! (assembly and from_coo are the offline path of every experiment).

use csrc_spmv::harness::smoke_suite;
use csrc_spmv::sparse::{Csr, Csrc};
use csrc_spmv::util::bench::Bench;

fn main() {
    let mut b = Bench::new("table1_dataset");
    for e in smoke_suite() {
        b.run(&format!("{}/generate", e.name), || {
            std::hint::black_box(e.build_coo());
        });
        let coo = e.build_coo();
        if coo.nrows == coo.ncols {
            let csr = Csr::from_coo(&coo);
            b.run(&format!("{}/csrc-from-csr", e.name), || {
                std::hint::black_box(Csrc::from_csr(&csr).unwrap());
            });
            let m = Csrc::from_csr(&csr).unwrap();
            b.record(&format!("{}/n", e.name), m.n as f64, "rows");
            b.record(&format!("{}/nnz", e.name), m.nnz() as f64, "nnz");
            b.record(&format!("{}/ws", e.name), (m.working_set_bytes() / 1024) as f64, "KB");
            b.record(
                &format!("{}/index-bytes-vs-csr", e.name),
                (m.ia.len() * 4 + m.ja.len() * 4) as f64 / ((csr.ia.len() + csr.ja.len()) * 4) as f64,
                "ratio",
            );
        }
    }
    b.finish();
}
